"""Batched fleet-solve benchmarks: vmapped many-system throughput.

Measures the new batched subsystem (``repro.core.batched`` +
``repro.serve.solver_engine``) against the naive python loop the paper's
target workload would otherwise run -- one plan/factor/solve round trip
per system -- across batch sizes {1, 8, 32, 128}:

  * ``fleet/loop_S``    -- python loop: per-system ``factor(plan_banded)``
                           + ``solve`` (the expensive stages re-run S times)
  * ``fleet/batched_S`` -- one ``batch_factor`` (vmapped device stages) +
                           one ``solve_batch`` over the stacked fleet
  * ``engine/*``        -- the serving path: bucketed heterogeneous fleet
                           with repeated matrices through ``SolverEngine``
                           (cache-hit rate + systems/s)

Run standalone (``python -m benchmarks.bench_batched [--smoke] [--out D]``)
to emit the machine-readable ``BENCH_batched.json`` trajectory file.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    SaPOptions,
    batch_factor,
    batch_plan,
    factor,
    plan_banded,
)
from repro.core.banded import band_matvec, random_banded  # noqa: E402
from repro.obs.cost import solver_stage_costs  # noqa: E402
from repro.serve import SolverEngine  # noqa: E402

from benchmarks.common import (  # noqa: E402
    Report,
    TracedReport,
    repo_root_default,
    stage_fractions,
    timeit,
)


def _fleet(s, n, k, d=1.0, seed=0):
    """S independent banded systems (same shape; distinct entries) + RHS."""
    bands, bs, xs = [], [], []
    rng = np.random.default_rng(seed)
    for i in range(s):
        band = jnp.asarray(random_banded(n, k, d=d, seed=seed + i), jnp.float32)
        x = rng.normal(size=n)
        bands.append(band)
        xs.append(x)
        bs.append(band_matvec(band, jnp.asarray(x, jnp.float32)))
    return bands, jnp.stack(bs), np.stack(xs)


def _fleet_cost(tr, bpl, res, opts) -> dict | None:
    """Per-stage cost records for one fleet/batched row.

    Roofline predictions come from the bucket's AOT cost analysis
    (:func:`repro.obs.cost.solver_stage_costs`); measured seconds come
    from the traced pass's factor.batch / krylov spans, with the krylov
    prediction rescaled from the lowered maxiter loop to the sweeps the
    batch actually ran (a lockstep vmapped solve runs max(iterations)
    sweeps for everyone).
    """
    try:
        costs = solver_stage_costs(
            (bpl.n, bpl.k, opts.p), s=bpl.s, opts=opts
        )
    except Exception:  # cost analysis must never sink the benchmark
        return None
    factor_s = sum(sp.duration_s for sp in tr.find("factor.batch"))
    krylov_s = sum(sp.duration_s for sp in tr.find("krylov"))
    sweeps = max(1, int(np.ceil(float(np.asarray(res.iterations).max()))))
    out = {
        "factor": costs["factor"].to_dict(measured_s=factor_s or None),
        "krylov": costs["krylov"].per_iteration().scale(sweeps)
        .to_dict(measured_s=krylov_s or None),
    }
    for sub in ("btf", "bts", "bcr"):  # kernel-level reference records
        if sub in costs:
            out[sub] = costs[sub].to_dict()
    return out


def bench_fleet(report: Report, smoke: bool = False):
    """Batched solve_batch vs the python loop of per-system factor+solve."""
    n, k, p = (512, 8, 4) if smoke else (2048, 8, 8)
    batches = (1, 8) if smoke else (1, 8, 32, 128)
    opts = SaPOptions(p=p, variant="C", tol=1e-6, maxiter=200)
    for s in batches:
        jax.clear_caches()
        bands, bmat, xs = _fleet(s, n, k)

        def loop_all():
            out = []
            for i in range(s):
                fac = factor(plan_banded(bands[i], opts))
                out.append(fac.solve(bmat[i]).x)
            return out

        us_loop = timeit(loop_all, warmup=1, iters=1)

        def batched_all():
            bfac = batch_factor(batch_plan(bands, opts))
            return bfac.solve_batch(bmat).x

        us_batched = timeit(batched_all, warmup=1, iters=3)

        # One traced pass (post-timing, so tracer overhead never pollutes
        # the us_per_call figures) to attribute wall time to stages.
        with report.tracing() as tr:
            bpl = batch_plan(bands, opts)
            bfac = batch_factor(bpl)
            res = bfac.solve_batch(bmat)
            jax.block_until_ready(res.x)
        err = float(np.abs(np.asarray(res.x)[:, :n] - xs).max())
        true_res = float(np.asarray(res.true_resnorm).max())
        report.add(f"fleet/loop_S={s}", us_loop, "replan+refactor per system")
        report.add(
            f"fleet/batched_S={s}",
            us_batched,
            f"speedup={us_loop / us_batched:.1f}x;"
            f"per_system_us={us_batched / s:.1f};maxerr={err:.1e};"
            f"conv={bool(np.asarray(res.converged).all())};"
            f"true_res={true_res:.3e};tol={opts.tol:g}",
            stages=stage_fractions(tr),
            cost=_fleet_cost(tr, bpl, res, opts),
        )


def bench_fused(report: Report, smoke: bool = False):
    """Fused factor+spike megakernel vs the kernel-sequence baseline.

    Same bucket, same fleet, ``fused_factor="off"`` (btf -> UL-btf ->
    spike solves) vs ``"on"`` (one fused pass,
    :mod:`repro.kernels.fused_spike`).  Each row carries the cost
    observatory's factor-stage record for its path: the fused pass never
    materializes the UL factors or the whole spikes, so its factor-stage
    HBM bytes must come in *below* the sequence baseline -- that byte gap
    is the committed, machine-checkable form of the megakernel claim
    (visible even on the jnp path, where XLA's cost analysis counts the
    same skipped materializations).
    """
    n, k, p, s = (512, 8, 4, 8) if smoke else (2048, 8, 8, 32)
    bands, bmat, xs = _fleet(s, n, k)
    stage = {}
    for mode in ("off", "on"):
        opts = SaPOptions(p=p, variant="C", tol=1e-6, maxiter=200,
                          fused_factor=mode)
        jax.clear_caches()
        bpl = batch_plan(bands, opts)

        def factor_only():
            return batch_factor(bpl).fac.pc

        us = timeit(factor_only, warmup=1, iters=3)
        res = batch_factor(bpl).solve_batch(bmat)
        err = float(np.abs(np.asarray(res.x)[:, :n] - xs).max())
        try:
            cost = solver_stage_costs((bpl.n, bpl.k, p), s=s, opts=opts)
        except Exception:
            cost = None
        rec = cost["factor"] if cost else None
        stage[mode] = rec
        label = "fused" if mode == "on" else "sequence"
        extra = ""
        if mode == "on" and stage["off"] is not None and rec is not None:
            saved = stage["off"].hbm_bytes - rec.hbm_bytes
            extra = (f";hbm_bytes_saved={saved:.3e}"
                     f";bytes_ratio={rec.hbm_bytes / stage['off'].hbm_bytes:.4f}")
        report.add(
            f"fused/factor_{label}_S={s}",
            us,
            f"maxerr={err:.1e};"
            f"conv={bool(np.asarray(res.converged).all())};"
            f"true_res={float(np.asarray(res.true_resnorm).max()):.3e};"
            f"tol={opts.tol:g}"
            + (f";factor_hbm_bytes={rec.hbm_bytes:.4e}" if rec else "")
            + extra,
            cost={"factor": rec.to_dict()} if rec else None,
        )


def bench_engine(report: Report, smoke: bool = False):
    """Serving path: heterogeneous fleet, repeated matrices, LRU cache."""
    n0, k0, steps, distinct = (256, 4, 3, 2) if smoke else (1024, 8, 8, 4)
    opts = SaPOptions(p=4, variant="C", tol=1e-6, maxiter=200)
    eng = SolverEngine(opts, max_batch=32, cache_size=64,
                       cost_accounting=True)
    rng = np.random.default_rng(3)
    mats = [
        np.float32(random_banded(n0 + 37 * i, k0 + (i % 2), d=1.1, seed=i))
        for i in range(distinct)
    ]
    with report.tracing() as tr:
        t0 = time.perf_counter()
        for _ in range(steps):  # time-stepping: same matrices, fresh RHS
            for band in mats:
                b = rng.normal(size=band.shape[0]).astype(np.float32)
                eng.submit_system(band, b)
        done = eng.run_until_drained()
        wall = time.perf_counter() - t0
    conv = all(r.result.converged for r in done)
    true_res = max(r.result.true_resnorm for r in done)
    report.add(
        "engine/fleet",
        wall * 1e6 / max(len(done), 1),
        f"solved={len(done)};hit_rate={eng.cache_hit_rate:.2f};"
        f"factored={eng.stats['factored_systems']};"
        f"steps={eng.stats['steps']};sys_per_s={len(done) / wall:.1f};"
        f"conv={conv};true_res={true_res:.3e};tol={opts.tol:g};"
        f"misconverged={eng.stats['misconverged']}",
        stages=stage_fractions(tr),
        cost=_engine_cost(eng),
    )


def _engine_cost(eng: SolverEngine) -> dict | None:
    """Fold the engine's accumulated cost totals into per-stage records.

    Measured seconds are the engine's own stage accounting
    (factor_seconds_total / solve_seconds_total); predictions are the
    roofline totals the engine accrued per step (S=1 linear-scaling
    factor model, sweeps x batch krylov model).
    """
    totals = eng.cost_snapshot()
    if not totals:
        return None
    measured = {
        "factor": eng.stats["factor_seconds_total"],
        "krylov": eng.stats["solve_seconds_total"],
    }
    out = {}
    for stage, t in totals.items():
        rec = dict(t)
        m = measured.get(stage)
        if m:
            rec["measured_s"] = round(m, 6)
            rec["roofline_frac"] = round(t["roofline_s"] / m, 6)
        out[stage] = rec
    return out


def run(report: Report, smoke: bool = False):
    bench_fleet(report, smoke)
    bench_fused(report, smoke)
    bench_engine(report, smoke)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / small batches (CI smoke job)")
    ap.add_argument("--out", default=str(repo_root_default()),
                    help="directory for BENCH_batched.json "
                         "(default: the repo root)")
    args = ap.parse_args(argv)
    report = TracedReport("batched")
    print("name,us_per_call,derived", flush=True)
    run(report, smoke=args.smoke)
    report.write_json(
        Path(args.out) / "BENCH_batched.json", meta={"smoke": args.smoke}
    )


if __name__ == "__main__":
    main()
