"""Kernel microbenchmarks: jnp reference path vs Pallas interpret path
(correctness-weighted; true kernel perf numbers require TPU hardware) and
LM step benches for the reduced configs."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import Report, timeit


def bench_btf(report: Report):
    rng = np.random.default_rng(0)
    for (p, m, k) in [(8, 16, 16), (16, 32, 32)]:
        d = jnp.asarray(rng.normal(size=(p, m, k, k)), jnp.float32) + 4 * jnp.eye(k)
        e = jnp.asarray(rng.normal(size=(p, m, k, k)) * 0.3, jnp.float32)
        f = jnp.asarray(rng.normal(size=(p, m, k, k)) * 0.3, jnp.float32)
        us_j = timeit(lambda: ops.block_tridiag_factor(d, e, f, impl="jnp").sinv)
        report.add(f"kernel/btf/jnp/P{p}xM{m}xK{k}", us_j,
                   f"flops~{p*m*8*k**3:.2e}")
        fac = ref.btf_ref(d, e, f)
        b = jnp.asarray(rng.normal(size=(p, m, k, 4)), jnp.float32)
        us_s = timeit(lambda: ops.block_tridiag_solve(fac, b, impl="jnp"))
        report.add(f"kernel/bts/jnp/P{p}xM{m}xK{k}", us_s, "")


def bench_scan_kernels(report: Report):
    rng = np.random.default_rng(1)
    b, h, t, dd = 2, 8, 512, 64
    r = jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32) * 0.5)
    u = jnp.asarray(rng.normal(size=(h, dd)), jnp.float32)
    s0 = jnp.zeros((b, h, dd, dd), jnp.float32)
    for chunk in (32, 64, 128):
        us = timeit(lambda: ops.wkv6(r, k, v, lw, u, s0, chunk=chunk,
                                     impl="jnp")[0])
        report.add(f"kernel/wkv6/jnp/T{t}/chunk{chunk}", us, "")
    # sequential reference for contrast (the chunked speedup story)
    us_seq = timeit(lambda: ref.wkv6_ref(r, k, v, lw, u, s0)[0], iters=1)
    report.add(f"kernel/wkv6/sequential/T{t}", us_seq, "")

    n, pd = 64, 64
    x = jnp.asarray(rng.normal(size=(b, h, t, pd)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    la = -jnp.exp(jnp.asarray(rng.normal(size=(b, h, t)), jnp.float32) * 0.5)
    ss = jnp.zeros((b, h, n, pd), jnp.float32)
    for chunk in (32, 64, 128):
        us = timeit(lambda: ops.ssd(x, bm, cm, la, ss, chunk=chunk,
                                    impl="jnp")[0])
        report.add(f"kernel/ssd/jnp/T{t}/chunk{chunk}", us, "")


def bench_lm_steps(report: Report):
    from repro.configs import get_config
    from repro.models import get_family

    rng = jax.random.PRNGKey(0)
    for arch in ("stablelm-1.6b", "rwkv6-1.6b", "zamba2-2.7b",
                 "deepseek-moe-16b"):
        cfg = get_config(arch, reduced=True)
        fam = get_family(cfg)
        params = fam.init(cfg, rng)
        batch = {"tokens": jax.random.randint(rng, (4, 128), 0, cfg.vocab)}

        def loss_fn(p):
            return fam.loss(cfg, p, batch)[0]

        step = jax.jit(jax.value_and_grad(loss_fn))
        us = timeit(lambda: step(params)[0])
        report.add(f"lm/train_step_reduced/{arch}", us, "b4xs128")
        cache = fam.init_cache(cfg, 4, 128)
        dstep = jax.jit(lambda p, c, t: fam.decode_step(cfg, p, c, t))
        tok = jnp.zeros((4, 1), jnp.int32)
        us = timeit(lambda: dstep(params, cache, tok)[0])
        report.add(f"lm/decode_step_reduced/{arch}", us, "b4")


def run(report: Report):
    bench_btf(report)
    bench_scan_kernels(report)
    bench_lm_steps(report)
