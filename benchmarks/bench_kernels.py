"""Kernel microbenchmarks: jnp reference path vs Pallas interpret path
(correctness-weighted; true kernel perf numbers require TPU hardware) and
LM step benches for the reduced configs."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import Report, timeit


def bench_btf(report: Report):
    rng = np.random.default_rng(0)
    for (p, m, k) in [(8, 16, 16), (16, 32, 32)]:
        d = jnp.asarray(rng.normal(size=(p, m, k, k)), jnp.float32) + 4 * jnp.eye(k)
        e = jnp.asarray(rng.normal(size=(p, m, k, k)) * 0.3, jnp.float32)
        f = jnp.asarray(rng.normal(size=(p, m, k, k)) * 0.3, jnp.float32)
        us_j = timeit(lambda: ops.block_tridiag_factor(d, e, f, impl="jnp").sinv)
        report.add(f"kernel/btf/jnp/P{p}xM{m}xK{k}", us_j,
                   f"flops~{p*m*8*k**3:.2e}")
        fac = ref.btf_ref(d, e, f)
        b = jnp.asarray(rng.normal(size=(p, m, k, 4)), jnp.float32)
        us_s = timeit(lambda: ops.block_tridiag_solve(fac, b, impl="jnp"))
        report.add(f"kernel/bts/jnp/P{p}xM{m}xK{k}", us_s, "")


def bench_bcr_chain(report: Report):
    """Sequential chain sweep vs log-depth cyclic reduction (the SaP-E
    reduced interface system).  The jnp chain sweep is an O(M) lax.scan;
    BCR is log2(M) levels of batched matmuls -- the depth gap is the
    point, and it widens with the chain length (= partition count)."""
    from repro.core.block_lu import btf_chain, bts_chain
    from repro.core.cyclic_reduction import bcr_factor, bcr_solve

    rng = np.random.default_rng(2)
    k = 16
    for m in (15, 63, 255, 1023):
        # shaped like the SaP-E reduced chain: identity diagonal blocks
        # plus spike-corner couplings well inside the unit disk
        d = jnp.asarray(rng.normal(size=(m, k, k)) * 0.1, jnp.float32) + jnp.eye(k)
        e = jnp.asarray(rng.normal(size=(m, k, k)) * 0.05, jnp.float32)
        f = jnp.asarray(rng.normal(size=(m, k, k)) * 0.05, jnp.float32)
        b = jnp.asarray(rng.normal(size=(m, k, 4)), jnp.float32)

        jf_seq = jax.jit(btf_chain)
        jf_bcr = jax.jit(bcr_factor)
        us_fs = timeit(lambda: jf_seq(d, e, f).sinv)
        us_fb = timeit(lambda: jf_bcr(d, e, f).root_inv)
        report.add(f"kernel/chain_factor/seq/M{m}xK{k}", us_fs, "lax.scan sweep")
        report.add(f"kernel/chain_factor/bcr/M{m}xK{k}", us_fb,
                   f"levels={max(m - 1, 0).bit_length()};"
                   f"speedup={us_fs / us_fb:.2f}x")

        fac_seq = jf_seq(d, e, f)
        fac_bcr = jf_bcr(d, e, f)
        js_seq = jax.jit(bts_chain)
        js_bcr = jax.jit(bcr_solve)
        x_seq = js_seq(fac_seq, b)
        x_bcr = js_bcr(fac_bcr, b)
        err = float(jnp.abs(x_seq - x_bcr).max())
        us_ss = timeit(lambda: js_seq(fac_seq, b))
        us_sb = timeit(lambda: js_bcr(fac_bcr, b))
        report.add(f"kernel/chain_solve/seq/M{m}xK{k}", us_ss, "")
        report.add(f"kernel/chain_solve/bcr/M{m}xK{k}", us_sb,
                   f"speedup={us_ss / us_sb:.2f}x;maxdiff={err:.1e}")


def bench_scan_kernels(report: Report):
    rng = np.random.default_rng(1)
    b, h, t, dd = 2, 8, 512, 64
    r = jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(b, h, t, dd)), jnp.float32) * 0.5)
    u = jnp.asarray(rng.normal(size=(h, dd)), jnp.float32)
    s0 = jnp.zeros((b, h, dd, dd), jnp.float32)
    for chunk in (32, 64, 128):
        us = timeit(lambda: ops.wkv6(r, k, v, lw, u, s0, chunk=chunk,
                                     impl="jnp")[0])
        report.add(f"kernel/wkv6/jnp/T{t}/chunk{chunk}", us, "")
    # sequential reference for contrast (the chunked speedup story)
    us_seq = timeit(lambda: ref.wkv6_ref(r, k, v, lw, u, s0)[0], iters=1)
    report.add(f"kernel/wkv6/sequential/T{t}", us_seq, "")

    n, pd = 64, 64
    x = jnp.asarray(rng.normal(size=(b, h, t, pd)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, h, t, n)), jnp.float32)
    la = -jnp.exp(jnp.asarray(rng.normal(size=(b, h, t)), jnp.float32) * 0.5)
    ss = jnp.zeros((b, h, n, pd), jnp.float32)
    for chunk in (32, 64, 128):
        us = timeit(lambda: ops.ssd(x, bm, cm, la, ss, chunk=chunk,
                                    impl="jnp")[0])
        report.add(f"kernel/ssd/jnp/T{t}/chunk{chunk}", us, "")


def bench_lm_steps(report: Report):
    from repro.configs import get_config
    from repro.models import get_family

    rng = jax.random.PRNGKey(0)
    for arch in ("stablelm-1.6b", "rwkv6-1.6b", "zamba2-2.7b",
                 "deepseek-moe-16b"):
        cfg = get_config(arch, reduced=True)
        fam = get_family(cfg)
        params = fam.init(cfg, rng)
        batch = {"tokens": jax.random.randint(rng, (4, 128), 0, cfg.vocab)}

        def loss_fn(p):
            return fam.loss(cfg, p, batch)[0]

        step = jax.jit(jax.value_and_grad(loss_fn))
        us = timeit(lambda: step(params)[0])
        report.add(f"lm/train_step_reduced/{arch}", us, "b4xs128")
        cache = fam.init_cache(cfg, 4, 128)
        dstep = jax.jit(lambda p, c, t: fam.decode_step(cfg, p, c, t))
        tok = jnp.zeros((4, 1), jnp.int32)
        us = timeit(lambda: dstep(params, cache, tok)[0])
        report.add(f"lm/decode_step_reduced/{arch}", us, "b4")


def run(report: Report):
    bench_btf(report)
    bench_bcr_chain(report)
    bench_scan_kernels(report)
    bench_lm_steps(report)
