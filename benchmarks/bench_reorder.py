"""Reordering benchmarks: paper Figs 4.4-4.6 + third-stage Tables 4.5/4.6.

DB vs scipy's min_weight_full_bipartite_matching (the MC64 stand-in) and
CM vs scipy's reverse_cuthill_mckee (the MC60 stand-in), over a suite of
generated sparse matrices; metrics mirror the paper: log2 speedup, diag
product quality, relative bandwidth difference r_K.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.core import reorder as R
from repro.core.sparse import random_sparse

from .common import Report, timeit


def _suite():
    specs = [
        (1000, 4.0, 1.5, 0), (2000, 6.0, 1.0, 1), (4000, 5.0, 2.0, 2),
        (2000, 8.0, 0.8, 3), (8000, 4.0, 1.2, 4),
    ]
    out = []
    for n, nnz, d, seed in specs:
        csr = random_sparse(n, avg_nnz_per_row=nnz, d=d, shuffle=True,
                            seed=seed)
        rng = np.random.default_rng(seed + 100)
        csr = R.permute_rows(csr, rng.permutation(n))  # scramble diagonal
        out.append((f"n{n}_s{seed}", csr))
    return out


def _log_diag_product(csr, perm):
    dense_diag = np.zeros(csr.n)
    rows = csr.row_ids()
    inv_rows = perm[np.arange(csr.n)]
    lookup = {(int(r), int(c)): v for r, c, v in zip(rows, csr.indices, csr.data)}
    for i in range(csr.n):
        dense_diag[i] = abs(lookup.get((int(perm[i]), i), 0.0))
    return float(np.sum(np.log(np.maximum(dense_diag, 1e-300))))


def bench_db(report: Report):
    for name, csr in _suite():
        us_ours = timeit(lambda: R.diagonal_boosting(csr), warmup=0, iters=1)
        perm = R.diagonal_boosting(csr)
        q_ours = _log_diag_product(csr, perm)

        m = sp.csr_matrix(
            (np.abs(csr.data), csr.indices, csr.indptr), shape=(csr.n, csr.n)
        )
        mw = m.copy()
        mw.data = -np.log(np.maximum(mw.data, 1e-300))

        def scipy_match():
            return csgraph.min_weight_full_bipartite_matching(mw)

        us_ref = timeit(scipy_match, warmup=0, iters=1)
        row, col = scipy_match()
        ref_perm = np.empty(csr.n, dtype=np.int64)
        ref_perm[col] = row
        q_ref = _log_diag_product(csr, ref_perm)
        s = np.log2(us_ref / us_ours)
        report.add(
            f"fig4.4/db/{name}", us_ours,
            f"log2_speedup_vs_mc64ref={s:.2f};quality_ours={q_ours:.1f};"
            f"quality_ref={q_ref:.1f}",
        )


def bench_cm(report: Report):
    for name, csr in _suite():
        sym = R.symmetrize(csr)
        us_ours = timeit(lambda: R.cuthill_mckee(sym), warmup=0, iters=1)
        perm = R.cuthill_mckee(sym)
        k_ours = R.half_bandwidth(R.permute_symmetric(csr, perm))

        m = sp.csr_matrix(
            (np.ones_like(sym.data), sym.indices, sym.indptr),
            shape=(csr.n, csr.n),
        )
        us_ref = timeit(
            lambda: csgraph.reverse_cuthill_mckee(m, symmetric_mode=True),
            warmup=0, iters=1,
        )
        rcm = np.asarray(
            csgraph.reverse_cuthill_mckee(m, symmetric_mode=True)
        )
        k_ref = R.half_bandwidth(R.permute_symmetric(csr, rcm))
        r_k = 100.0 * (k_ref - k_ours) / max(k_ours, 1)  # paper Eq (r_K)
        report.add(
            f"fig4.5/cm/{name}", us_ours,
            f"K_ours={k_ours};K_mc60ref={k_ref};r_K={r_k:.1f}%;"
            f"log2_speedup={np.log2(us_ref/us_ours):.2f}",
        )


def bench_third_stage(report: Report):
    """Tables 4.5/4.6: per-partition K_i reduction and solve speedup."""
    import jax.numpy as jnp

    from repro.core import SaPOptions, solve_banded

    for name, csr in _suite()[:3]:
        perm_db = R.diagonal_boosting(csr)
        c2 = R.permute_rows(csr, perm_db)
        perm_cm = R.cuthill_mckee(R.symmetrize(c2))
        c3 = R.permute_symmetric(c2, perm_cm)
        k = max(R.half_bandwidth(c3), 1)
        p = 8
        part = -(-csr.n // p)
        n_pad = part * p
        band = np.zeros((n_pad, 2 * k + 1))
        band[: csr.n] = R.csr_to_band(c3, k)
        band[csr.n :, k] = 1.0
        us3 = timeit(lambda: R.third_stage(band, k, p, part), warmup=0, iters=1)
        perm3, k_i = R.third_stage(band, k, p, part)
        report.add(
            f"table4.5/third_stage/{name}", us3,
            f"K_before={k};K_i_max_after={int(k_i.max())};"
            f"K_i={','.join(map(str, k_i.tolist()))}",
        )


def run(report: Report):
    bench_db(report)
    bench_cm(report)
    bench_third_stage(report)
