"""Serving soak benchmark: async service vs sequential engine loop.

Drives the same mixed-bucket fleet workload -- several distinct
Jacobians across two compiled buckets, re-solved over many "time steps"
with fresh right-hand sides, arriving from concurrent clients -- through
two serving disciplines:

  * ``serve/sequential`` -- the synchronous pattern the repo had before
    the async service: each arrival is ``submit()`` + ``run_until_
    drained()`` before the client proceeds (no batching across arrivals,
    no host/device overlap).
  * ``serve/async``      -- :class:`repro.serve.service.AsyncSolverService`:
    clients submit from threads and block on futures; the background
    drain thread batches concurrent arrivals per bucket and overlaps
    host-side fingerprinting/bucketing with in-flight device solves.

The acceptance row reports the solves/sec ratio (target >= 1.5x), the
deadline-miss count at the default load (target 0), and dumps the full
metrics snapshot -- queue-depth / time-in-queue / batch-occupancy
histograms, hit rate -- into the ``BENCH_serve.json`` trajectory file.

Run standalone: ``python -m benchmarks.bench_serve [--smoke] [--out D]``.
"""

from __future__ import annotations

import argparse
import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import SaPOptions  # noqa: E402
from repro.core.banded import random_banded  # noqa: E402
from repro.serve import AsyncSolverService, SolverEngine  # noqa: E402

from benchmarks.common import Report, repo_root_default  # noqa: E402


def _workload(smoke: bool):
    """Mixed-bucket fleet: distinct Jacobians x repeated time steps."""
    if smoke:
        shapes, steps, clients = [(256, 4), (300, 4), (512, 8)], 4, 4
    else:
        shapes, steps, clients = [(1024, 8), (1500, 8), (2048, 16)], 8, 8
    mats = [
        np.float32(random_banded(n, k, d=1.1, seed=7 * i + j))
        for i, (n, k) in enumerate(shapes)
        for j in range(2)  # two distinct Jacobians per shape
    ]
    rng = np.random.default_rng(0)
    reqs = []
    for s in range(steps):
        for band in mats:
            reqs.append((band, rng.normal(size=band.shape[0])
                         .astype(np.float32)))
    return reqs, clients


def _opts():
    return SaPOptions(p=4, variant="C", tol=1e-6, maxiter=300)


def _run_sequential(reqs):
    eng = SolverEngine(_opts(), max_batch=32, cache_size=64)
    t0 = time.perf_counter()
    done = []
    for band, b in reqs:  # one arrival at a time: submit, then drain
        eng.submit_system(band, b)
        done.extend(eng.run_until_drained())
    wall = time.perf_counter() - t0
    assert all(r.result.converged for r in done)
    true_res = max(r.result.true_resnorm for r in done)
    return wall, len(done), eng, true_res


def _run_async(reqs, clients, deadline_s=120.0):
    svc = AsyncSolverService(
        _opts(), max_batch=32, cache_size=64, queue_cap=256
    )
    chunks = [reqs[i::clients] for i in range(clients)]
    futs_by_client = [[] for _ in range(clients)]

    def client(cid):
        for band, b in chunks[cid]:
            futs_by_client[cid].append(
                svc.submit(band, b, deadline_s=deadline_s, timeout=300)
            )

    t0 = time.perf_counter()
    threads = [
        threading.Thread(target=client, args=(c,)) for c in range(clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    outs = [f.result(timeout=600) for futs in futs_by_client for f in futs]
    wall = time.perf_counter() - t0
    assert all(o.converged for o in outs)
    true_res = max(o.true_resnorm for o in outs)
    svc.close()
    return wall, len(outs), svc, true_res


def run(report: Report, smoke: bool = False) -> dict:
    reqs, clients = _workload(smoke)

    # warm the jit caches for every bucket once, outside both timings --
    # the comparison is serving discipline, not compile time
    warm = SolverEngine(_opts(), max_batch=32, cache_size=64)
    for band, b in reqs:
        warm.submit_system(band, b)
    warm.run_until_drained()

    tol = _opts().tol
    wall_seq, n_seq, eng, tr_seq = _run_sequential(reqs)
    sps_seq = n_seq / wall_seq
    report.add(
        "serve/sequential",
        wall_seq * 1e6 / n_seq,
        f"solved={n_seq};sys_per_s={sps_seq:.1f};"
        f"hit_rate={eng.cache_hit_rate:.2f};steps={eng.stats['steps']};"
        f"conv=True;true_res={tr_seq:.3e};tol={tol:g}",
    )

    wall_async, n_async, svc, tr_async = _run_async(reqs, clients)
    snap = svc.snapshot()
    sps_async = n_async / wall_async
    misses = int(snap["counters"].get("deadline_misses", 0))
    misconv = int(snap["counters"].get("misconverged_total", 0))
    occ = snap["histograms"]["batch_occupancy"]
    report.add(
        "serve/async",
        wall_async * 1e6 / n_async,
        f"solved={n_async};sys_per_s={sps_async:.1f};"
        f"speedup={sps_async / sps_seq:.2f}x;"
        f"deadline_misses={misses};clients={clients};"
        f"hit_rate={snap['derived']['cache_hit_rate']:.2f};"
        f"occupancy_mean={occ['mean']:.2f};"
        f"queue_p90={snap['histograms']['queue_depth']['p90']:.0f};"
        f"conv=True;true_res={tr_async:.3e};tol={tol:g};"
        f"misconverged={misconv}",
    )
    return {
        "smoke": smoke,
        "clients": clients,
        "requests": len(reqs),
        "speedup": round(sps_async / sps_seq, 3),
        "deadline_misses": misses,
        "misconverged_total": misconv,
        "async_metrics": snap,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few steps (CI smoke job)")
    ap.add_argument("--out", default=str(repo_root_default()),
                    help="directory for BENCH_serve.json "
                         "(default: the repo root)")
    args = ap.parse_args(argv)
    report = Report("serve")
    print("name,us_per_call,derived", flush=True)
    meta = run(report, smoke=args.smoke)
    report.write_json(Path(args.out) / "BENCH_serve.json", meta=meta)
    if meta["speedup"] < 1.5:
        print(f"WARNING: async speedup {meta['speedup']}x below 1.5x target",
              flush=True)


if __name__ == "__main__":
    main()
