"""Dense-banded solver benchmarks: paper Tables 4.1-4.3 / Figs 4.1-4.3.

CPU-scaled sizes (the full-size cells live in the dry-run/roofline path):
  * P sweep   (Table 4.1):  N=8192, K=16, d=1.0, P in {2..32}, C vs D
  * d sweep   (Table 4.2):  N=8192, K=16, P=16,  d in {0.06..1.2}
  * NxK sweep (Table 4.3):  SaP vs the direct banded solver (P=1 block-
    tridiag factor+solve == the sequential "MKL stand-in")
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import SaPOptions, factor, plan_banded, solve_banded
from repro.core.banded import band_matvec, band_to_block_tridiag, random_banded
from repro.core.block_lu import btf_ref, bts_ref

from .common import Report, timeit


def _make_cached_solver(band, opts):
    """Factor ONCE via the lifecycle API so repeated calls hit the jit
    cache -- separates execution time from plan/factor/compile time."""
    fac = factor(plan_banded(band, opts))
    return lambda b: fac.solve(b).x


def _system(n, k, d, seed=0):
    band = jnp.asarray(random_banded(n, k, d=d, seed=seed), jnp.float32)
    rng = np.random.default_rng(seed + 1)
    xstar = rng.normal(size=n)
    b = jnp.asarray(np.asarray(band_matvec(band, jnp.asarray(xstar))),
                    jnp.float32)
    return band, b, xstar


def _direct_banded(band, b):
    """Sequential direct banded solve (P=1) -- the MKL stand-in."""
    k = (band.shape[1] - 1) // 2
    bt = band_to_block_tridiag(band, k, 1)
    fac = btf_ref(bt.d, bt.e, bt.f)
    rb = jnp.concatenate([b, jnp.zeros(bt.n_pad - b.shape[0], b.dtype)])
    x = bts_ref(fac, rb.reshape(1, bt.m, bt.k, 1))
    return x.reshape(-1)[: b.shape[0]]


def bench_p_sweep(report: Report):
    import jax

    jax.clear_caches()
    n, k = 8192, 16
    band, b, xstar = _system(n, k, 1.0)
    for p in (2, 4, 8, 16, 32):
        for variant in ("C", "D"):
            opts = SaPOptions(p=p, variant=variant, tol=1e-6, maxiter=200)
            sol = solve_banded(band, b, opts)  # warm correctness check
            err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
            solve = _make_cached_solver(band, opts)
            us = timeit(solve, b)  # cached-executable time (paper's T_Kry)
            report.add(
                f"table4.1/p_sweep/P={p}/{variant}",
                us,
                f"iters={sol.iterations:.2f};relerr={err:.1e}",
            )


def bench_d_sweep(report: Report):
    """Variants C/D/E/auto across the dominance sweep.  ``auto`` resolves
    per cell from the d-factor estimate (C at d >= 1, E below); the info
    string records both so the policy crossover is visible in the table."""
    import jax

    jax.clear_caches()
    n, k, p = 4096, 16, 16
    for d in (0.06, 0.1, 0.3, 0.6, 1.0, 1.2):
        band, b, xstar = _system(n, k, d)
        for variant in ("C", "D", "E", "auto"):
            opts = SaPOptions(p=p, variant=variant, tol=1e-6, maxiter=500)
            sol = solve_banded(band, b, opts)
            err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
            fac = factor(plan_banded(band, opts))
            us = timeit(lambda rhs: fac.solve(rhs).x, b, iters=1)
            # exact sweep count from the recorded residual track (the
            # fractional `iters` is BiCGStab(2) quarter-iteration
            # bookkeeping; non-NaN history entries are completed sweeps)
            hist = np.asarray(fac.solve(b, record_history=True).history)
            krylov_iters = int(np.count_nonzero(~np.isnan(hist)))
            report.add(
                f"table4.2/d_sweep/d={d}/{variant}",
                us,
                f"iters={sol.iterations:.2f};krylov_iters={krylov_iters};"
                f"relerr={err:.1e};"
                f"conv={sol.converged};variant={sol.info['variant']};"
                f"red={sol.info['reduced_solver']};"
                f"d_factor={sol.info['d_factor']:.3f}",
            )


def bench_nk_sweep(report: Report):
    import jax

    for n in (2048, 4096):
        jax.clear_caches()  # bound the XLA CPU jit code cache
        for k in (8, 16):
            band, b, xstar = _system(n, k, 1.0)
            us_direct = timeit(lambda: _direct_banded(band, b))
            xd = np.asarray(_direct_banded(band, b))
            err_d = np.linalg.norm(xd - xstar) / np.linalg.norm(xstar)
            report.add(f"table4.3/direct/N={n}/K={k}", us_direct,
                       f"relerr={err_d:.1e}")
            for variant in ("C", "D"):
                opts = SaPOptions(p=8, variant=variant, tol=1e-6)
                sol = solve_banded(band, b, opts)
                solve = _make_cached_solver(band, opts)
                us = timeit(solve, b)
                report.add(
                    f"table4.3/sap{variant}/N={n}/K={k}",
                    us,
                    f"speedup_vs_direct={us_direct/us:.2f};iters={sol.iterations:.2f}",
                )


def bench_amortization(report: Report, nrhs: int = 16):
    """Factor-once/solve-many vs re-planning per RHS (the lifecycle win).

    The one-shot path re-runs plan + factor + Krylov for every RHS; the
    lifecycle path factors once and amortizes it over ``nrhs`` batched
    solves (paper Fig. 3.1: T_DB..T_LU paid once, T_Kry per solve).
    """
    import jax

    jax.clear_caches()
    n, k = 4096, 16
    band, b, xstar = _system(n, k, 1.0)
    rng = np.random.default_rng(7)
    xs = rng.normal(size=(n, nrhs))
    bmat = jnp.asarray(
        np.asarray(band_matvec(band, jnp.asarray(xs, jnp.float32))), jnp.float32
    )
    opts = SaPOptions(p=8, variant="C", tol=1e-6, maxiter=200)

    def one_shot_all():
        return [solve_banded(band, bmat[:, j], opts).x for j in range(nrhs)]

    us_oneshot = timeit(one_shot_all, warmup=1, iters=1)

    fac = factor(plan_banded(band, opts))
    us_amortized = timeit(lambda: fac.solve_many(bmat).x, warmup=1, iters=3)

    res = fac.solve_many(bmat)
    err = np.abs(np.asarray(res.x) - xs).max()
    report.add(f"lifecycle/one_shot_x{nrhs}", us_oneshot, "replan per RHS")
    report.add(
        f"lifecycle/factor_once_x{nrhs}",
        us_amortized,
        f"speedup={us_oneshot / us_amortized:.1f}x;maxerr={err:.1e};"
        f"conv={bool(res.converged.all())}",
    )


def run(report: Report):
    bench_p_sweep(report)
    bench_d_sweep(report)
    bench_nk_sweep(report)
    bench_amortization(report)
