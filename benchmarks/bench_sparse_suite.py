"""Sparse solver suite: paper Sec 4.3.3 / Table A.2 analogue.

A batch of generated sparse systems (banded provenance scrambled by random
permutations, varying dominance/density) solved by SaP::TPU (C and D) and
by a dense direct solve (the PARDISO stand-in at these sizes).  Reports
robustness counts and times; the paper's 1% relative-accuracy criterion
decides success.  Also emits the stage profile (Fig 4.7/4.8 analogue).

Uses the plan/factor/solve lifecycle: the DB/CM analysis is planned once
per system and shared by the C and D variants (factor-once amortization),
so the reported times split into plan / factor+solve.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SaPOptions, factor, plan
from repro.core import reorder as R
from repro.core.banded import random_rhs
from repro.core.sparse import random_sparse

from .common import Report


def _suite():
    specs = [
        ("ancf_like", 2000, 5.8, 1.2, True),
        ("fe_mild", 1500, 6.0, 0.8, True),
        ("dominant", 3000, 4.0, 2.0, True),
        ("weak_diag", 1000, 6.0, 0.3, True),
        ("wide_band", 1500, 12.0, 1.0, True),
        ("tiny", 512, 4.0, 1.0, True),
        ("mid_sparse", 4000, 3.0, 1.5, True),
        ("dense_band", 1024, 16.0, 1.0, False),
    ]
    for i, (name, n, nnz, d, shuf) in enumerate(specs):
        csr = random_sparse(n, avg_nnz_per_row=nnz, d=d, shuffle=shuf, seed=i)
        rng = np.random.default_rng(1000 + i)
        csr = R.permute_rows(csr, rng.permutation(n))
        yield name, csr


def run(report: Report):
    solved = {"sapC": 0, "sapD": 0, "sapE": 0, "sapauto": 0, "direct": 0}
    total = 0
    for name, csr in _suite():
        total += 1
        xstar = np.asarray(random_rhs(csr.n))  # paper's parabola solution
        dense = csr.to_dense()
        b = dense @ xstar

        # direct dense solve (PARDISO stand-in)
        t0 = time.perf_counter()
        try:
            xd = np.linalg.solve(dense, b)
            us_direct = (time.perf_counter() - t0) * 1e6
            err_d = np.linalg.norm(xd - xstar) / np.linalg.norm(xstar)
            ok_d = err_d <= 0.01
        except np.linalg.LinAlgError:
            us_direct, ok_d = float("nan"), False
        solved["direct"] += ok_d
        report.add(f"tableA.2/direct/{name}", us_direct, f"ok={ok_d}")

        # plan once per system; both variants share the DB/CM analysis
        t0 = time.perf_counter()
        try:
            pl = plan(csr, SaPOptions(p=8, tol=1e-8, maxiter=500))
            us_plan = (time.perf_counter() - t0) * 1e6
            report.add(f"tableA.2/plan/{name}", us_plan,
                       f"K={pl.k};k_reorder={pl.info['k_after_reorder']}")
        except Exception as e:
            pl = None
            report.add(f"tableA.2/plan/{name}", float("nan"),
                       f"error={type(e).__name__}")

        for variant in ("C", "D", "E", "auto"):
            t0 = time.perf_counter()
            try:
                if pl is None:
                    raise RuntimeError("plan failed")
                pv = dataclasses.replace(
                    pl, opts=dataclasses.replace(pl.opts, variant=variant)
                )
                fac = factor(pv)
                res = fac.solve(jnp.asarray(b, jnp.float32))
                jax.block_until_ready(res.x)  # async dispatch: sync before timing
                us = (time.perf_counter() - t0) * 1e6
                x = np.asarray(res.x)
                err = np.linalg.norm(x - xstar) / np.linalg.norm(xstar)
                ok = bool(res.converged) and err <= 0.01
                info = (f"ok={ok};iters={float(res.iterations):.2f};"
                        f"K={fac.k};relerr={err:.1e};variant={fac.variant};"
                        f"d_factor={float(fac.d_factor):.3f}")
            except Exception as e:  # robustness accounting, like the paper
                us, ok, info = float("nan"), False, f"ok=False;error={type(e).__name__}"
            solved[f"sap{variant}"] += ok
            report.add(f"tableA.2/sap{variant}/{name}", us, info)

    report.add(
        "tableA.2/robustness", 0.0,
        f"sapC={solved['sapC']}/{total};sapD={solved['sapD']}/{total};"
        f"sapE={solved['sapE']}/{total};sapAuto={solved['sapauto']}/{total};"
        f"direct={solved['direct']}/{total}",
    )


def profile_stages(report: Report):
    """Fig 4.7/4.8: % of time per stage (DB, CM, Asmbl, LU, Kry).

    The plan is assembled by hand from the reorder primitives so each
    front-end stage can be timed; factor + solve go through the lifecycle
    handles exactly as production code would.
    """
    csr = random_sparse(3000, avg_nnz_per_row=6.0, d=1.2, shuffle=True, seed=7)
    rng = np.random.default_rng(99)
    csr = R.permute_rows(csr, rng.permutation(csr.n))
    xstar = np.asarray(random_rhs(csr.n))
    b = csr.to_dense() @ xstar

    from repro.core import CsrOperator, SaPOptions, factor
    from repro.core.sap import SaPPlan

    t = {}
    t0 = time.perf_counter()
    perm = R.diagonal_boosting(csr)
    c2 = R.permute_rows(csr, perm)
    t["DB"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sym = R.cuthill_mckee(R.symmetrize(c2))
    c3 = R.permute_symmetric(c2, sym)
    t["CM"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    k = max(R.half_bandwidth(c3), 1)
    band = R.csr_to_band(c3, k)
    t["Asmbl"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    opts = SaPOptions(p=8, variant="C", tol=1e-8, maxiter=300)
    pl = SaPPlan(
        op=CsrOperator.from_csr(c3),
        band_pc=jnp.asarray(band, jnp.float32),
        k=k,
        n=c3.n,
        b_perm=perm[sym],
        x_perm=np.argsort(sym),
        opts=opts,
        info={},
    )
    fac = factor(pl)
    jax.block_until_ready(fac.pc.lu.sinv)
    t["LU+SPK"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = fac.solve(jnp.asarray(b, jnp.float32))
    jax.block_until_ready(res.x)
    t["Kry"] = time.perf_counter() - t0
    total = sum(t.values())
    pct = ";".join(f"{k2}={100*v/total:.1f}%" for k2, v in t.items())
    report.add("fig4.7/stage_profile", total * 1e6, pct)
