"""Sparse solver suite: paper Sec 4.3.3 / Table A.2 analogue.

A batch of generated sparse systems (banded provenance scrambled by random
permutations, varying dominance/density) solved by SaP::TPU (C and D) and
by a dense direct solve (the PARDISO stand-in at these sizes).  Reports
robustness counts and times; the paper's 1% relative-accuracy criterion
decides success.  Also emits the stage profile (Fig 4.7/4.8 analogue).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import SaPOptions, solve_sparse
from repro.core import reorder as R
from repro.core.banded import random_rhs
from repro.core.sparse import random_sparse

from .common import Report


def _suite():
    specs = [
        ("ancf_like", 2000, 5.8, 1.2, True),
        ("fe_mild", 1500, 6.0, 0.8, True),
        ("dominant", 3000, 4.0, 2.0, True),
        ("weak_diag", 1000, 6.0, 0.3, True),
        ("wide_band", 1500, 12.0, 1.0, True),
        ("tiny", 512, 4.0, 1.0, True),
        ("mid_sparse", 4000, 3.0, 1.5, True),
        ("dense_band", 1024, 16.0, 1.0, False),
    ]
    for i, (name, n, nnz, d, shuf) in enumerate(specs):
        csr = random_sparse(n, avg_nnz_per_row=nnz, d=d, shuffle=shuf, seed=i)
        rng = np.random.default_rng(1000 + i)
        csr = R.permute_rows(csr, rng.permutation(n))
        yield name, csr


def run(report: Report):
    solved = {"sapC": 0, "sapD": 0, "direct": 0}
    total = 0
    for name, csr in _suite():
        total += 1
        xstar = np.asarray(random_rhs(csr.n))  # paper's parabola solution
        dense = csr.to_dense()
        b = dense @ xstar

        # direct dense solve (PARDISO stand-in)
        t0 = time.perf_counter()
        try:
            xd = np.linalg.solve(dense, b)
            us_direct = (time.perf_counter() - t0) * 1e6
            err_d = np.linalg.norm(xd - xstar) / np.linalg.norm(xstar)
            ok_d = err_d <= 0.01
        except np.linalg.LinAlgError:
            us_direct, ok_d = float("nan"), False
        solved["direct"] += ok_d
        report.add(f"tableA.2/direct/{name}", us_direct, f"ok={ok_d}")

        for variant in ("C", "D"):
            t0 = time.perf_counter()
            try:
                sol = solve_sparse(
                    csr, b,
                    SaPOptions(p=8, variant=variant, tol=1e-8, maxiter=500),
                )
                us = (time.perf_counter() - t0) * 1e6
                err = np.linalg.norm(sol.x - xstar) / np.linalg.norm(xstar)
                ok = bool(sol.converged and err <= 0.01)
                info = (f"ok={ok};iters={sol.iterations:.2f};"
                        f"K={sol.k};relerr={err:.1e}")
            except Exception as e:  # robustness accounting, like the paper
                us, ok, info = float("nan"), False, f"ok=False;error={type(e).__name__}"
            solved[f"sap{variant}"] += ok
            report.add(f"tableA.2/sap{variant}/{name}", us, info)

    report.add(
        "tableA.2/robustness", 0.0,
        f"sapC={solved['sapC']}/{total};sapD={solved['sapD']}/{total};"
        f"direct={solved['direct']}/{total}",
    )


def profile_stages(report: Report):
    """Fig 4.7/4.8: % of time per stage (DB, CM, Asmbl, LU, Kry)."""
    csr = random_sparse(3000, avg_nnz_per_row=6.0, d=1.2, shuffle=True, seed=7)
    rng = np.random.default_rng(99)
    csr = R.permute_rows(csr, rng.permutation(csr.n))
    xstar = np.asarray(random_rhs(csr.n))
    b = csr.to_dense() @ xstar

    import jax.numpy as jnp

    from repro.core.banded import band_to_block_tridiag
    from repro.core.sap import _csr_matvec_fn, _krylov_solve

    t = {}
    t0 = time.perf_counter()
    perm = R.diagonal_boosting(csr)
    c2 = R.permute_rows(csr, perm)
    t["DB"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sym = R.cuthill_mckee(R.symmetrize(c2))
    c3 = R.permute_symmetric(c2, sym)
    t["CM"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    k = max(R.half_bandwidth(c3), 1)
    band = R.csr_to_band(c3, k)
    t["Asmbl"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    from repro.core.spike import build_preconditioner

    bt = band_to_block_tridiag(jnp.asarray(band, jnp.float32), k, 8)
    pc = build_preconditioner(bt, "C")
    import jax

    jax.block_until_ready(pc.lu.sinv)
    t["LU+SPK"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    b_r = jnp.asarray((b[perm])[sym], jnp.float32)
    from repro.core.krylov import bicgstab2

    mv = _csr_matvec_fn(c3)

    def precond(r):
        rp = jnp.concatenate([r, jnp.zeros(bt.n_pad - r.shape[0], r.dtype)])
        return pc.apply(rp)[: r.shape[0]]

    res = bicgstab2(mv, b_r, precond=precond, tol=1e-8, maxiter=300)
    jax.block_until_ready(res.x)
    t["Kry"] = time.perf_counter() - t0
    total = sum(t.values())
    pct = ";".join(f"{k2}={100*v/total:.1f}%" for k2, v in t.items())
    report.add("fig4.7/stage_profile", total * 1e6, pct)
