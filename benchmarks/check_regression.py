"""Perf-regression gate over the bench trajectory.

Compares each row of the given BENCH_*.json run documents against the
median of its matched baselines in BENCH_history.jsonl -- same bench,
same row name, same :func:`benchmarks.trajectory.platform_key`, same
smoke flag, recorded after the last covering bless marker -- and fails
when the current ``us_per_call`` exceeds ``tolerance x`` that median.

Rows with no matched baseline are *skipped*, not failed: a fresh
platform (or a brand-new bench row) has nothing to regress against and
starts accruing history instead.  Only slowdowns gate; a speedup just
prints.  Intentional regressions (e.g. trading speed for accuracy) are
accepted by appending a bless marker::

    python -m benchmarks.trajectory bless --history BENCH_history.jsonl \
        --note "why this slowdown is intended"

Exit status 1 on any regression, 0 otherwise -- wired into the CI
bench-smoke job after the smoke benches write their run docs.
"""

from __future__ import annotations

import argparse
import json
import statistics
from pathlib import Path

from benchmarks.trajectory import (
    baseline_records,
    history_records,
    load_history,
)

# Default gate: 1.5x the baseline median.  CI passes a looser value
# (shared runners have real multi-x wall-clock variance); a dedicated
# perf box can tighten it.
DEFAULT_TOLERANCE = 1.5


class RegressionError(AssertionError):
    """A bench row ran slower than tolerance x its baseline median."""


def check_doc(doc: dict, history: list[dict],
              tolerance: float = DEFAULT_TOLERANCE) -> list[dict]:
    """Check one run document; returns per-row verdicts.

    Each verdict: {bench, row, platform, status, us_per_call,
    [baseline_us, ratio, n_baseline]} with status one of "ok",
    "regression", "no-baseline".
    """
    verdicts = []
    for rec in history_records(doc):
        base = baseline_records(history, rec["bench"], rec["row"],
                                rec["platform"], rec["smoke"])
        v = {"bench": rec["bench"], "row": rec["row"],
             "platform": rec["platform"], "us_per_call": rec["us_per_call"]}
        if not base:
            v["status"] = "no-baseline"
            verdicts.append(v)
            continue
        baseline_us = statistics.median(r["us_per_call"] for r in base)
        ratio = (rec["us_per_call"] / baseline_us if baseline_us > 0
                 else float("inf"))
        v.update(baseline_us=round(baseline_us, 1),
                 ratio=round(ratio, 3), n_baseline=len(base))
        v["status"] = "regression" if ratio > tolerance else "ok"
        verdicts.append(v)
    return verdicts


def check(docs, history_path,
          tolerance: float = DEFAULT_TOLERANCE) -> list[dict]:
    """Check run docs (dicts or paths) against a history file.

    Raises :class:`RegressionError` naming every offending row; returns
    the full verdict list otherwise.
    """
    history = load_history(history_path)
    verdicts = []
    for doc in docs:
        if not isinstance(doc, dict):
            doc = json.loads(Path(doc).read_text())
        verdicts.extend(check_doc(doc, history, tolerance))
    bad = [v for v in verdicts if v["status"] == "regression"]
    if bad:
        lines = [
            f"  {v['bench']}/{v['row']} [{v['platform']}]: "
            f"{v['us_per_call']:.1f}us vs baseline "
            f"{v['baseline_us']:.1f}us (x{v['ratio']:.2f} > "
            f"tolerance x{tolerance:.2f}, n={v['n_baseline']})"
            for v in bad
        ]
        raise RegressionError(
            f"{len(bad)} bench row(s) regressed beyond tolerance "
            f"x{tolerance:.2f}:\n" + "\n".join(lines)
            + "\n(intentional? bless with: python -m benchmarks.trajectory"
              " bless --history <file> --note '<why>')"
        )
    return verdicts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("docs", nargs="+", help="current BENCH_*.json run docs")
    ap.add_argument("--history", default="BENCH_history.jsonl")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="fail when us_per_call > tolerance * baseline "
                         f"median (default {DEFAULT_TOLERANCE})")
    args = ap.parse_args(argv)
    try:
        verdicts = check(args.docs, args.history, args.tolerance)
    except RegressionError as err:
        print(f"FAIL: {err}")
        return 1
    for v in verdicts:
        if v["status"] == "no-baseline":
            print(f"skip {v['bench']}/{v['row']} [{v['platform']}]: "
                  f"no matched baseline ({v['us_per_call']:.1f}us recorded)")
        else:
            print(f"ok   {v['bench']}/{v['row']}: {v['us_per_call']:.1f}us "
                  f"vs {v['baseline_us']:.1f}us baseline "
                  f"(x{v['ratio']:.2f}, n={v['n_baseline']})")
    print(f"regression gate passed ({len(verdicts)} rows, "
          f"tolerance x{args.tolerance:.2f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
