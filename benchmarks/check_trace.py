"""Validate a Chrome/Perfetto trace_event JSON file (CI bench-smoke gate).

Checks the schema invariants a trace viewer relies on:

  * the document is ``{"traceEvents": [...]}`` with a non-empty list
  * every event carries ``ph``/``pid``/``tid``; duration events (``B``/
    ``E``) also carry a numeric ``ts`` and a ``name``
  * per thread, every ``E`` closes an open ``B`` of the same name and no
    ``B`` is left open (events are sorted by ``ts`` first -- file order
    is not load-bearing; retroactive spans may interleave)

plus two repo-specific gates:

  * ``--require NAME...``: each named span must appear as a completed
    ``B``/``E`` pair (the tentpole's acceptance list: reorder, factor.lu,
    factor.spike, krylov)
  * ``--bench BENCH.json``: at least one row carries a ``stages`` dict
    and every ``stages`` dict sums to ~1.0

Exit code 0 on success; prints the first violation and exits 1 otherwise.

    python -m benchmarks.check_trace trace.json \
        --require reorder factor.lu factor.spike krylov \
        --bench BENCH_batched.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


class TraceError(ValueError):
    """A trace/bench file violated the checked schema."""


def load_events(path) -> list:
    doc = json.loads(Path(path).read_text())
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise TraceError(f"{path}: no traceEvents list")
    return events


def validate_events(events: list) -> dict:
    """Check B/E pairing + required fields; return {name: count} of pairs."""
    by_tid: dict = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None or "pid" not in ev or "tid" not in ev:
            raise TraceError(f"event {i}: missing ph/pid/tid: {ev}")
        if ph in ("B", "E"):
            if not isinstance(ev.get("ts"), (int, float)):
                raise TraceError(f"event {i}: {ph} without numeric ts: {ev}")
            if ph == "B" and not ev.get("name"):
                raise TraceError(f"event {i}: B without name: {ev}")
            by_tid.setdefault(ev["tid"], []).append((ev["ts"], i, ev))
    if not by_tid:
        raise TraceError("no B/E duration events in trace")
    pairs: dict = {}
    for tid, evs in by_tid.items():
        evs.sort(key=lambda t: (t[0], t[1]))  # by ts; file order breaks ties
        open_spans: list = []
        for _, i, ev in evs:
            if ev["ph"] == "B":
                open_spans.append(ev["name"])
            else:
                name = ev.get("name")
                # close the most recent open B of the same name (retroactive
                # request spans may overlap without strict nesting)
                for j in range(len(open_spans) - 1, -1, -1):
                    if open_spans[j] == name:
                        open_spans.pop(j)
                        pairs[name] = pairs.get(name, 0) + 1
                        break
                else:
                    raise TraceError(
                        f"tid {tid}: E {name!r} (event {i}) closes no open B"
                    )
        if open_spans:
            raise TraceError(f"tid {tid}: unclosed B spans: {open_spans}")
    return pairs


def check_required(pairs: dict, required: list) -> None:
    missing = [name for name in required if not pairs.get(name)]
    if missing:
        raise TraceError(
            f"required spans missing from trace: {missing} "
            f"(present: {sorted(pairs)})"
        )


def check_bench_stages(path, tol: float = 0.02) -> int:
    """Every ``stages`` dict sums to ~1.0; at least one row carries one."""
    doc = json.loads(Path(path).read_text())
    n = 0
    for row in doc.get("rows", []):
        stages = row.get("stages")
        if stages is None:
            continue
        n += 1
        total = sum(stages.values())
        if abs(total - 1.0) > tol:
            raise TraceError(
                f"{path}: row {row['name']!r} stages sum to {total:.4f}, "
                f"expected ~1.0: {stages}"
            )
    if n == 0:
        raise TraceError(f"{path}: no row carries a 'stages' dict")
    return n


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace_event JSON file")
    ap.add_argument("--require", nargs="*", default=[],
                    help="span names that must appear as B/E pairs")
    ap.add_argument("--bench", default=None,
                    help="BENCH_*.json whose rows must carry stage "
                         "fractions summing to ~1.0")
    args = ap.parse_args(argv)
    try:
        pairs = validate_events(load_events(args.trace))
        check_required(pairs, args.require)
        print(f"{args.trace}: OK -- {sum(pairs.values())} spans, "
              f"{len(pairs)} distinct names")
        if args.bench:
            n = check_bench_stages(args.bench)
            print(f"{args.bench}: OK -- {n} rows with stage fractions")
    except TraceError as e:
        print(f"TRACE INVALID: {e}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
