"""Shared benchmark utilities: timing + CSV reporting + JSON trajectory.

Every benchmark runner prints the human-readable ``name,us_per_call,
derived`` CSV it always has, and can additionally serialize the same rows
to a machine-readable ``BENCH_<name>.json`` via :meth:`Report.write_json`
-- the per-PR perf trajectory artifact (uploaded by the CI bench-smoke
job, diffable across commits).
"""

from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (jax arrays block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class Report:
    def __init__(self, name: str = ""):
        self.name = name
        self.rows = []
        self.records = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        self.records.append(
            {"name": name, "us_per_call": round(us_per_call, 1),
             "derived": _parse_derived(derived)}
        )
        print(row, flush=True)

    def write_json(self, path, meta: dict | None = None) -> Path:
        """Serialize the collected rows as a BENCH_*.json trajectory file.

        Refuses to write (raises :class:`MisconvergedBench`) when any row
        claims convergence with a true residual above ``10 * tol`` -- a
        benchmark that publishes a converged-but-wrong solve is worse
        than no benchmark, and this check is what makes the CI
        bench-smoke job fail on a misconvergence regression.
        """
        check_rows(self.records)
        path = Path(path)
        doc = {
            "bench": self.name or path.stem,
            "unix_time": int(time.time()),
            "platform": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "machine": platform.machine(),
                "python": platform.python_version(),
            },
            "meta": meta or {},
            "rows": self.records,
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}", flush=True)
        return path


class MisconvergedBench(RuntimeError):
    """A benchmark row reported converged=True with true_res > 10 * tol."""


def check_rows(records) -> None:
    """Reject rows that claim convergence while the true residual fails.

    ``conv`` parses to the string ``"True"``/``"False"`` (not a float);
    ``true_res`` and ``tol`` are numeric when present.  Rows that do not
    carry all three fields are left alone.
    """
    for rec in records:
        d = rec.get("derived", {})
        conv, true_res, tol = d.get("conv"), d.get("true_res"), d.get("tol")
        if conv not in ("True", True):
            continue
        if not isinstance(true_res, float) or not isinstance(tol, float):
            continue
        if true_res > 10.0 * tol:
            raise MisconvergedBench(
                f"row {rec['name']!r}: converged=True but "
                f"true_res={true_res:g} > 10 * tol={tol:g}"
            )


def repo_root_default() -> Path:
    """Default --out directory: the repository root, so the committed
    BENCH_*.json trajectory files land where the ROADMAP expects them."""
    return Path(__file__).resolve().parent.parent


def _parse_derived(derived: str) -> dict:
    """Split a ``k1=v1;k2=v2`` derived string into a dict (numbers where
    possible); free-form fragments land under ``"note"``."""
    out: dict = {}
    notes = []
    for frag in filter(None, derived.split(";")):
        if "=" in frag:
            key, val = frag.split("=", 1)
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
        else:
            notes.append(frag)
    if notes:
        out["note"] = ";".join(notes)
    return out
