"""Shared benchmark utilities: timing + CSV reporting."""

from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (jax arrays block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class Report:
    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        print(row, flush=True)
