"""Shared benchmark utilities: timing + CSV reporting + JSON trajectory.

Every benchmark runner prints the human-readable ``name,us_per_call,
derived`` CSV it always has, and can additionally serialize the same rows
to a machine-readable ``BENCH_<name>.json`` via :meth:`Report.write_json`
-- the per-PR perf trajectory artifact (uploaded by the CI bench-smoke
job, diffable across commits).
"""

from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (jax arrays block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


class Report:
    def __init__(self, name: str = ""):
        self.name = name
        self.rows = []
        self.records = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        self.records.append(
            {"name": name, "us_per_call": round(us_per_call, 1),
             "derived": _parse_derived(derived)}
        )
        print(row, flush=True)

    def write_json(self, path, meta: dict | None = None) -> Path:
        """Serialize the collected rows as a BENCH_*.json trajectory file."""
        path = Path(path)
        doc = {
            "bench": self.name or path.stem,
            "unix_time": int(time.time()),
            "platform": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "machine": platform.machine(),
                "python": platform.python_version(),
            },
            "meta": meta or {},
            "rows": self.records,
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}", flush=True)
        return path


def _parse_derived(derived: str) -> dict:
    """Split a ``k1=v1;k2=v2`` derived string into a dict (numbers where
    possible); free-form fragments land under ``"note"``."""
    out: dict = {}
    notes = []
    for frag in filter(None, derived.split(";")):
        if "=" in frag:
            key, val = frag.split("=", 1)
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
        else:
            notes.append(frag)
    if notes:
        out["note"] = ";".join(notes)
    return out
