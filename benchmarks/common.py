"""Shared benchmark utilities: timing + CSV reporting + JSON trajectory.

Every benchmark runner prints the human-readable ``name,us_per_call,
derived`` CSV it always has, and can additionally serialize the same rows
to a machine-readable ``BENCH_<name>.json`` via :meth:`Report.write_json`
-- the per-PR perf trajectory artifact (uploaded by the CI bench-smoke
job, diffable across commits).
"""

from __future__ import annotations

import contextlib
import json
import platform
import time
from pathlib import Path

import jax

from repro.obs.trace import Tracer, use_tracer


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (jax arrays block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


# Span-name -> stage-bucket map for BENCH_*.json ``stages`` breakdowns.
# Only *root-visible* lifecycle spans appear here: the inner factor spans
# (factor.lu / factor.spike / factor.reduced) are no-ops when factoring
# runs under jit (the batched path), so the coarse factor.* roots carry
# the wall time we can actually attribute.
STAGE_SPANS = {
    "reorder.db": "db",
    "reorder.cm": "cm",
    "factor": "lu_spk",
    "factor.batch": "lu_spk",
    "factor.lu": "lu_spk",
    "factor.fused": "lu_spk",
    "factor.spike": "lu_spk",
    "factor.reduced": "lu_spk",
    "factor.split": "lu_spk",
    "krylov": "krylov",
}


def stage_fractions(tracer: Tracer) -> dict | None:
    """Fold a tracer's spans into {db, cm, lu_spk, krylov} wall fractions.

    Sums self-exclusive time per mapped span name (children mapped to the
    same stage are not double counted because only top-most mapped spans
    in each root chain are taken), then normalizes to sum to 1.0.
    Returns None when no mapped span was recorded -- a bench row measured
    without tracing gets no bogus stages dict.
    """
    totals: dict[str, float] = {}

    def visit(sp, inside_mapped: bool):
        stage = STAGE_SPANS.get(sp.name)
        if stage is not None and not inside_mapped:
            totals[stage] = totals.get(stage, 0.0) + sp.duration_s
            inside = True
        else:
            inside = inside_mapped
        for ch in sp.children:
            visit(ch, inside)

    for root in tracer.roots():
        visit(root, False)
    total = sum(totals.values())
    if total <= 0.0:
        return None
    return {k: round(v / total, 4) for k, v in sorted(totals.items())}


class Report:
    def __init__(self, name: str = ""):
        self.name = name
        self.rows = []
        self.records = []

    def add(self, name: str, us_per_call: float, derived: str = "",
            stages: dict | None = None, cost: dict | None = None):
        row = f"{name},{us_per_call:.1f},{derived}"
        self.rows.append(row)
        rec = {"name": name, "us_per_call": round(us_per_call, 1),
               "derived": _parse_derived(derived)}
        if stages:
            rec["stages"] = stages
        if cost:
            rec["cost"] = cost
        self.records.append(rec)
        print(row, flush=True)

    @contextlib.contextmanager
    def tracing(self):
        """Yield a tracer scoped to one measurement block.

        The base Report yields a *disabled*, non-activated tracer: bench
        code writes ``with report.tracing() as tr: ...; report.add(...,
        stages=stage_fractions(tr))`` uniformly, and stages simply come
        out None.  :class:`TracedReport` overrides this to install a live
        tracer so the same rows gain a ``stages`` dict.
        """
        yield Tracer(enabled=False)

    def write_json(self, path, meta: dict | None = None) -> Path:
        """Serialize the collected rows as a BENCH_*.json trajectory file.

        Refuses to write (raises :class:`MisconvergedBench`) when any row
        claims convergence with a true residual above ``10 * tol`` -- a
        benchmark that publishes a converged-but-wrong solve is worse
        than no benchmark, and this check is what makes the CI
        bench-smoke job fail on a misconvergence regression.
        """
        check_rows(self.records)
        path = Path(path)
        doc = {
            "bench": self.name or path.stem,
            "unix_time": int(time.time()),
            "platform": {
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "machine": platform.machine(),
                "python": platform.python_version(),
            },
            "meta": meta or {},
            "rows": self.records,
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path}", flush=True)
        return path


class TracedReport(Report):
    """A Report whose :meth:`tracing` blocks run under a live tracer.

    Each ``with report.tracing() as tr:`` block installs a fresh enabled
    :class:`~repro.obs.trace.Tracer` process-wide for its duration, so
    the instrumented library spans (reorder.*, factor.*, krylov) land on
    ``tr`` and :func:`stage_fractions` can fold them into the row's
    ``stages`` dict.
    """

    @contextlib.contextmanager
    def tracing(self):
        tracer = Tracer()
        with use_tracer(tracer):
            yield tracer


class MisconvergedBench(RuntimeError):
    """A benchmark row reported converged=True with true_res > 10 * tol."""


def check_rows(records) -> None:
    """Reject rows that claim convergence while the true residual fails.

    ``conv`` parses to the string ``"True"``/``"False"`` (not a float);
    ``true_res`` and ``tol`` are numeric when present.  Rows that do not
    carry all three fields are left alone.
    """
    for rec in records:
        d = rec.get("derived", {})
        conv, true_res, tol = d.get("conv"), d.get("true_res"), d.get("tol")
        if conv not in ("True", True):
            continue
        if not isinstance(true_res, float) or not isinstance(tol, float):
            continue
        if true_res > 10.0 * tol:
            raise MisconvergedBench(
                f"row {rec['name']!r}: converged=True but "
                f"true_res={true_res:g} > 10 * tol={tol:g}"
            )


def repo_root_default() -> Path:
    """Default --out directory: the repository root, so the committed
    BENCH_*.json trajectory files land where the ROADMAP expects them."""
    return Path(__file__).resolve().parent.parent


def _parse_derived(derived: str) -> dict:
    """Split a ``k1=v1;k2=v2`` derived string into a dict (numbers where
    possible); free-form fragments land under ``"note"``."""
    out: dict = {}
    notes = []
    for frag in filter(None, derived.split(";")):
        if "=" in frag:
            key, val = frag.split("=", 1)
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
        else:
            notes.append(frag)
    if notes:
        out["note"] = ";".join(notes)
    return out
