"""Run the full multi-pod dry-run sweep: every (arch x shape x mesh) cell.

Each cell runs in its own subprocess (clean XLA device-count env; a
compile failure or OOM in one cell cannot kill the sweep) and writes
``results/dryrun/<arch>__<shape>__<mesh>.json``.  Existing files are
skipped, so the sweep is resumable.

Usage:
  python -m benchmarks.dryrun_sweep --mesh single          # 16x16
  python -m benchmarks.dryrun_sweep --mesh multi           # 2x16x16
  python -m benchmarks.dryrun_sweep --mesh single --only rwkv6-1.6b
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
RESULTS = REPO / "results" / "dryrun"


def cells():
    sys.path.insert(0, str(REPO / "src"))
    from repro.configs import ARCHS, get_config
    from repro.configs.sap_solver import SOLVER_SHAPES
    from repro.models import SHAPES, supports_shape

    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            if supports_shape(cfg, s):
                out.append((arch, s.name))
    for s in SOLVER_SHAPES:
        out.append(("sap-solver", s))
    return out


def run_cell(arch: str, shape: str, mesh: str, timeout: int, devices: int,
             extra: list[str]) -> dict:
    tag = f"{arch}__{shape}__{mesh}"
    out_file = RESULTS / f"{tag}.json"
    if out_file.exists():
        return {"cell": tag, "status": "cached"}
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--out", str(out_file),
    ] + (["--multi-pod"] if mesh == "multi" else []) + extra
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["REPRO_DRYRUN_DEVICES"] = str(devices)
    t0 = time.time()
    try:
        proc = subprocess.run(
            cmd, capture_output=True, text=True, timeout=timeout, env=env
        )
        dt = time.time() - t0
        if proc.returncode != 0:
            err = {"cell": tag, "status": "failed", "wall_s": round(dt, 1),
                   "stderr": proc.stderr[-4000:]}
            out_file.with_suffix(".err.json").write_text(json.dumps(err, indent=2))
            return err
        return {"cell": tag, "status": "ok", "wall_s": round(dt, 1)}
    except subprocess.TimeoutExpired:
        return {"cell": tag, "status": "timeout", "wall_s": timeout}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--only", default=None, help="substring filter on arch")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--devices", type=int, default=None)
    args, extra = ap.parse_known_args()
    devices = args.devices or (512 if args.mesh == "multi" else 256)

    RESULTS.mkdir(parents=True, exist_ok=True)
    todo = cells()
    if args.only:
        todo = [c for c in todo if args.only in c[0]]
    print(f"{len(todo)} cells on mesh={args.mesh}", flush=True)
    for arch, shape in todo:
        res = run_cell(arch, shape, args.mesh, args.timeout, devices, extra)
        print(json.dumps(res), flush=True)


if __name__ == "__main__":
    main()
