"""Perf hillclimbing driver: runs dry-run variants of the three chosen
cells and collects the roofline terms per iteration.

    python -m benchmarks.hillclimb [--only A|B|C]

Writes results/hillclimb/<cell>__<tag>.json.  The hypothesis->measure log
lives in EXPERIMENTS.md section Perf.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
OUT = REPO / "results" / "hillclimb"

CELLS = {
    # A: worst footprint + every term bad: mixtral train
    "A": [
        ("mixtral-8x22b", "train_4k", "A0_baseline", []),
        ("mixtral-8x22b", "train_4k", "A1_zero1", ["--zero1"]),
        ("mixtral-8x22b", "train_4k", "A2_zero1_master",
         ["--zero1", "--master-weights"]),
        ("mixtral-8x22b", "train_4k", "A3_zero1_master_micro8",
         ["--zero1", "--master-weights", "--microbatches", "8"]),
        ("mixtral-8x22b", "train_4k", "A4_remat_dots",
         ["--zero1", "--master-weights", "--microbatches", "8",
          "--remat", "dots"]),
        ("mixtral-8x22b", "train_4k", "A5_fsdp",
         ["--zero1", "--master-weights", "--fsdp"]),
        ("mixtral-8x22b", "train_4k", "A6_fsdp_micro2",
         ["--zero1", "--master-weights", "--fsdp", "--microbatches", "2"]),
    ],
    # B: worst memory/compute skew: rwkv train (chunk-size = the paper's P
    # tradeoff inside the SaP-scan)
    "B": [
        ("rwkv6-1.6b", "train_4k", "B0_baseline_chunk64", []),
        ("rwkv6-1.6b", "train_4k", "B1_chunk32", ["--ssm-chunk", "32"]),
        ("rwkv6-1.6b", "train_4k", "B2_chunk16", ["--ssm-chunk", "16"]),
        ("rwkv6-1.6b", "train_4k", "B3_chunk8", ["--ssm-chunk", "8"]),
        ("rwkv6-1.6b", "train_4k", "B4_chunk16_bf16",
         ["--ssm-chunk", "16", "--scan-dtype", "bfloat16"]),
    ],
    # C: the paper's own workload: variant + mixed precision + partitioning
    "C": [
        ("sap-solver", "dense_200k", "C0_baseline_C_f32", ["--variant", "C"]),
        ("sap-solver", "dense_200k", "C1_variant_D", ["--variant", "D"]),
        ("sap-solver", "dense_200k", "C2_C_bf16",
         ["--variant", "C", "--precond-dtype", "bfloat16"]),
        ("sap-solver", "dense_200k", "C3_D_bf16",
         ["--variant", "D", "--precond-dtype", "bfloat16"]),
        ("sap-solver", "dense_200k", "C4_C_p4",
         ["--variant", "C", "--p-per-device", "4"]),
    ],
}


def run_one(arch, shape, tag, extra, devices=256):
    out_file = OUT / f"{tag}.json"
    if out_file.exists():
        return {"tag": tag, "status": "cached"}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", str(out_file)] + extra
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["REPRO_DRYRUN_DEVICES"] = str(devices)
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=3000,
                          env=env)
    if proc.returncode != 0:
        err = {"tag": tag, "status": "failed", "stderr": proc.stderr[-3000:]}
        out_file.with_suffix(".err.json").write_text(json.dumps(err, indent=2))
        return err
    row = json.loads(out_file.read_text())
    r = row["roofline"]
    return {
        "tag": tag, "status": "ok",
        "compute_s": round(r["compute_s"], 4),
        "memory_s": round(r["memory_s"], 4),
        "collective_s": round(r["collective_s"], 4),
        "bottleneck": r["bottleneck"],
        "mem_gib": round(row["memory"].get("total_per_device", 0) / 2**30, 2),
        "useful": round(r["useful_ratio"], 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    OUT.mkdir(parents=True, exist_ok=True)
    for cell, runs in CELLS.items():
        if args.only and cell != args.only:
            continue
        for arch, shape, tag, extra in runs:
            print(json.dumps(run_one(arch, shape, tag, extra)), flush=True)


if __name__ == "__main__":
    main()
