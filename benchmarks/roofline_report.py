"""Assemble roofline reports: dry-run sweep tables + solver cost tables.

Two sources, one renderer:

  * ``results/dryrun/*.json`` (from benchmarks/dryrun_sweep.py) -- the
    markdown tables for EXPERIMENTS.md (``--table roofline`` /
    ``--table dryrun``).  The results directory is a flag now
    (``--results-dir``), not a hard-coded path, so sweeps written
    anywhere (CI artifacts, scratch dirs) render the same.
  * a ``BENCH_batched.json`` run document (``--bench``) -- the per-stage
    solver cost table: HLO-derived flops / HBM bytes / arithmetic
    intensity, the roofline-predicted seconds, and the achieved
    roofline fraction for rows that carry measured wall time (the
    ``cost`` records attached by benchmarks/bench_batched.py).

``--out FILE`` writes the rendered markdown instead of printing it.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_RESULTS = REPO / "results" / "dryrun"


def load(results_dir: Path, mesh: str):
    rows = []
    for f in sorted(Path(results_dir).glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(rows):
    hdr = (
        "| arch | shape | kind | flops/dev | HBM B/dev | coll B/dev | "
        "compute | memory | collective | bound | useful | mem/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        mem = r.get("memory", {}).get("total_per_device", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('kind','?')} | "
            f"{rf['flops']:.2e} | {fmt_b(rf['bytes_accessed'])} | "
            f"{fmt_b(rf['coll_bytes'])} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"**{rf['bottleneck']}** | {rf['useful_ratio']:.2f} | "
            f"{fmt_b(mem)} |"
        )
    return hdr + "\n".join(lines)


def dryrun_table(rows):
    hdr = (
        "| arch | shape | mesh | chips | compile | params | mem/dev | "
        "all-reduce | all-gather | reduce-scatter | all-to-all | permute |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if "roofline" not in r:
            continue
        cd = r["roofline"]["coll_detail"]

        def g(op):
            e = cd.get(op)
            return fmt_b(e["bytes"]) if e else "-"

        mem = r.get("memory", {}).get("total_per_device", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']}s | {r.get('params', r.get('n','-'))} | {fmt_b(mem)} | "
            f"{g('all-reduce')} | {g('all-gather')} | {g('reduce-scatter')} | "
            f"{g('all-to-all')} | {g('collective-permute')} |"
        )
    return hdr + "\n".join(lines)


def cost_table(doc: dict) -> str:
    """Per-stage solver cost table from a BENCH_batched.json document."""
    hw = "?"
    hdr = (
        "| row | stage | flops | HBM bytes | intensity | roofline | "
        "measured | achieved | bound |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for row in doc.get("rows", []):
        for stage, c in (row.get("cost") or {}).items():
            hw = c.get("hw", hw)
            intensity = c.get("intensity")
            measured = c.get("measured_s")
            frac = c.get("roofline_frac")
            lines.append(
                f"| {row['name']} | {stage} | {c['flops']:.3g} | "
                f"{fmt_b(c['hbm_bytes'])} | "
                + (f"{intensity:.2f}" if intensity is not None else "-")
                + f" | {fmt_s(c['roofline_s'])} | "
                + (fmt_s(measured) if measured else "-")
                + " | "
                + (f"{frac:.1%}" if frac is not None else "-")
                + f" | {c.get('bottleneck', '-')} |"
            )
    if not lines:
        return ("no `cost` records in this run document -- rerun "
                "benchmarks/bench_batched.py from a build with the cost "
                "observatory (repro.obs.cost)\n")
    title = (f"Per-stage solver cost ({doc.get('bench', '?')}, "
             f"hardware model `{hw}`, achieved = roofline_s / measured_s)\n\n")
    return title + hdr + "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--results-dir", default=str(DEFAULT_RESULTS),
                    help="dry-run sweep results directory "
                         "(default: <repo>/results/dryrun)")
    ap.add_argument("--bench", default=None,
                    help="render the per-stage cost table from this "
                         "BENCH_batched.json instead of the sweep tables")
    ap.add_argument("--out", default=None,
                    help="write the rendered markdown here instead of stdout")
    args = ap.parse_args(argv)
    if args.bench:
        text = cost_table(json.loads(Path(args.bench).read_text()))
    else:
        results_dir = Path(args.results_dir)
        if not results_dir.exists():
            text = (f"no results under {results_dir} -- run "
                    "benchmarks/dryrun_sweep.py first (or pass "
                    "--results-dir)\n")
        else:
            rows = load(results_dir, args.mesh)
            table = roofline_table if args.table == "roofline" else dryrun_table
            text = table(rows)
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(text if text.endswith("\n") else text + "\n")
        print(f"wrote {out}")
    else:
        print(text)


if __name__ == "__main__":
    main()
