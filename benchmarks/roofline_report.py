"""Assemble the roofline report from results/dryrun/*.json.

Produces the markdown tables for EXPERIMENTS.md (section Dry-run and
section Roofline) and prints cell summaries.  The roofline table is
single-pod (per the assignment); the multi-pod columns prove pod-axis
sharding (collective schedule includes cross-pod traffic).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
RESULTS = REPO / "results" / "dryrun"


def load(mesh: str):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x):
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(rows):
    hdr = (
        "| arch | shape | kind | flops/dev | HBM B/dev | coll B/dev | "
        "compute | memory | collective | bound | useful | mem/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        mem = r.get("memory", {}).get("total_per_device", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('kind','?')} | "
            f"{rf['flops']:.2e} | {fmt_b(rf['bytes_accessed'])} | "
            f"{fmt_b(rf['coll_bytes'])} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"**{rf['bottleneck']}** | {rf['useful_ratio']:.2f} | "
            f"{fmt_b(mem)} |"
        )
    return hdr + "\n".join(lines)


def dryrun_table(rows):
    hdr = (
        "| arch | shape | mesh | chips | compile | params | mem/dev | "
        "all-reduce | all-gather | reduce-scatter | all-to-all | permute |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if "roofline" not in r:
            continue
        cd = r["roofline"]["coll_detail"]

        def g(op):
            e = cd.get(op)
            return fmt_b(e["bytes"]) if e else "-"

        mem = r.get("memory", {}).get("total_per_device", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']}s | {r.get('params', r.get('n','-'))} | {fmt_b(mem)} | "
            f"{g('all-reduce')} | {g('all-gather')} | {g('reduce-scatter')} | "
            f"{g('all-to-all')} | {g('collective-permute')} |"
        )
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", default="roofline", choices=["roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.table == "roofline":
        print(roofline_table(rows))
    else:
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
