"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sizes are CPU-scaled; the
full-scale (arch x shape x mesh) numbers come from the dry-run/roofline
pipeline (see benchmarks/dryrun_sweep.py + benchmarks/roofline_report.py).
"""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from benchmarks.common import Report, TracedReport, repo_root_default  # noqa: E402
from benchmarks.trajectory import append_history  # noqa: E402


def main() -> None:
    import jax

    report = Report()
    out = repo_root_default()  # committed trajectory files live at the root
    history = out / "BENCH_history.jsonl"  # append-only perf trajectory
    print("name,us_per_call,derived", flush=True)

    # bench_solver and bench_batched track the cross-PR perf trajectory:
    # their rows also land in machine-readable BENCH_*.json files and the
    # append-only BENCH_history.jsonl that feeds the regression gate
    # (benchmarks/check_regression.py).
    from benchmarks import bench_solver  # noqa: E402

    solver_report = TracedReport("solver")
    bench_solver.run(solver_report)
    append_history(solver_report.write_json(out / "BENCH_solver.json"),
                   history)
    jax.clear_caches()

    from benchmarks import bench_batched  # noqa: E402

    batched_report = TracedReport("batched")
    bench_batched.run(batched_report)
    append_history(batched_report.write_json(out / "BENCH_batched.json"),
                   history)
    jax.clear_caches()

    from benchmarks import bench_serve  # noqa: E402

    serve_report = Report("serve")
    bench_serve.run(serve_report)
    append_history(serve_report.write_json(out / "BENCH_serve.json"),
                   history)
    jax.clear_caches()

    from benchmarks import bench_reorder  # noqa: E402

    bench_reorder.run(report)

    from benchmarks import bench_sparse_suite  # noqa: E402

    bench_sparse_suite.run(report)
    bench_sparse_suite.profile_stages(report)
    jax.clear_caches()

    from benchmarks import bench_kernels  # noqa: E402

    bench_kernels.run(report)


if __name__ == "__main__":
    main()
