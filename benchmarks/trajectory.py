"""Append-only bench trajectory: BENCH_history.jsonl + baselines.

The BENCH_*.json files each hold ONE run.  This module folds them into a
durable, append-only JSONL history so the perf trajectory across commits
is queryable: one record per (bench, row) per run, keyed by a platform
string, plus *bless markers* that reset the regression baseline after an
intentional perf change.

Record shapes (one JSON object per line):

  data row   {"bench", "row", "platform", "unix_time", "us_per_call",
              "smoke": bool}
  bless mark {"bless": true, "unix_time", "note", ["bench"], ["row"]}

A bless marker without ``bench``/``row`` covers everything; with them it
covers only the matching rows.  :func:`baseline_records` returns the data
rows *after* the last covering bless marker, which is what
``benchmarks.check_regression`` compares against.

CLI::

    python -m benchmarks.trajectory append BENCH_batched.json \
        --history BENCH_history.jsonl
    python -m benchmarks.trajectory bless --history BENCH_history.jsonl \
        --note "batched factor now AOT-cached"
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def platform_key(platform: dict) -> str:
    """Collapse a BENCH_*.json platform dict to a comparable key.

    Timings are only comparable on like hardware: backend (cpu/gpu/tpu),
    machine architecture, and device count.  Python/jax versions are
    deliberately excluded -- version bumps should not orphan the
    baseline; a real perf regression from an upgrade *should* trip the
    gate.
    """
    return (
        f"{platform.get('backend', '?')}/{platform.get('machine', '?')}"
        f"/d{platform.get('device_count', 1)}"
    )


def history_records(doc: dict) -> list[dict]:
    """Flatten one BENCH_*.json document into history data rows."""
    key = platform_key(doc.get("platform", {}))
    smoke = bool(doc.get("meta", {}).get("smoke", False))
    out = []
    for row in doc.get("rows", []):
        out.append({
            "bench": doc.get("bench", "?"),
            "row": row["name"],
            "platform": key,
            "unix_time": doc.get("unix_time", 0),
            "us_per_call": row["us_per_call"],
            "smoke": smoke,
        })
    return out


def append_history(doc, history_path) -> int:
    """Append one BENCH doc (dict or path to json) to the history file.

    Returns the number of rows appended.  Creation is implicit; appends
    are line-atomic enough for the single-writer CI/bench use.
    """
    if not isinstance(doc, dict):
        doc = json.loads(Path(doc).read_text())
    recs = history_records(doc)
    path = Path(history_path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as fh:
        for rec in recs:
            fh.write(json.dumps(rec, sort_keys=True) + "\n")
    return len(recs)


def append_bless(history_path, note: str = "", bench: str | None = None,
                 row: str | None = None, unix_time: int | None = None) -> None:
    """Append a bless marker: baselines before it stop counting."""
    mark: dict = {"bless": True,
                  "unix_time": int(time.time()) if unix_time is None
                  else unix_time}
    if note:
        mark["note"] = note
    if bench:
        mark["bench"] = bench
    if row:
        mark["row"] = row
    path = Path(history_path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as fh:
        fh.write(json.dumps(mark, sort_keys=True) + "\n")


def load_history(path) -> list[dict]:
    """Read every record (data rows and bless markers), skipping blank
    and malformed lines rather than dying on a torn append."""
    path = Path(path)
    if not path.exists():
        return []
    out = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue
    return out


def _covers(mark: dict, bench: str, row: str) -> bool:
    if mark.get("bench") not in (None, bench):
        return False
    return mark.get("row") in (None, row)


def baseline_records(history: list[dict], bench: str, row: str,
                     platform: str, smoke: bool) -> list[dict]:
    """Matching data rows after the last covering bless marker.

    File order is append order, so "after the last bless" is a simple
    scan: a covering marker clears the matches collected so far.
    """
    out: list[dict] = []
    for rec in history:
        if rec.get("bless"):
            if _covers(rec, bench, row):
                out.clear()
            continue
        if (rec.get("bench") == bench and rec.get("row") == row
                and rec.get("platform") == platform
                and bool(rec.get("smoke", False)) == smoke):
            out.append(rec)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    ap_append = sub.add_parser("append", help="fold BENCH_*.json docs in")
    ap_append.add_argument("docs", nargs="+", help="BENCH_*.json paths")
    ap_append.add_argument("--history", default="BENCH_history.jsonl")

    ap_bless = sub.add_parser(
        "bless", help="reset the regression baseline from here on")
    ap_bless.add_argument("--history", default="BENCH_history.jsonl")
    ap_bless.add_argument("--note", default="")
    ap_bless.add_argument("--bench", default=None)
    ap_bless.add_argument("--row", default=None)

    args = ap.parse_args(argv)
    if args.cmd == "append":
        total = 0
        for doc in args.docs:
            n = append_history(doc, args.history)
            print(f"appended {n} rows from {doc} -> {args.history}")
            total += n
        return 0 if total else 1
    append_bless(args.history, note=args.note, bench=args.bench,
                 row=args.row)
    print(f"blessed {args.history}"
          + (f" (bench={args.bench} row={args.row})"
             if args.bench or args.row else " (all rows)"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
