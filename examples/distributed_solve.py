"""Distributed SaP solve across a device mesh (the paper's technique as a
first-class distributed workload; partitions span every mesh axis).

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        PYTHONPATH=src python examples/distributed_solve.py
"""

import os
import sys
from pathlib import Path

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_to_dense, random_banded
from repro.core.distributed import build_dist_sap, solve_step_fn
from repro.launch.mesh import make_test_mesh


def main():
    ndev = len(jax.devices())
    mesh = make_test_mesh((2, ndev // 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} ({ndev} devices)")

    n, k = 4096, 12
    band = random_banded(n, k, d=1.0, seed=0)
    dense = np.asarray(band_to_dense(jnp.asarray(band)))
    xstar = np.random.default_rng(0).normal(size=n)
    b = dense @ xstar

    for variant in ("C", "D"):
        dsap = build_dist_sap(mesh, n, k, variant=variant, p_per_device=2)
        band_p, b_p, parts = dsap.shard_band(band, b)
        step = jax.jit(solve_step_fn(dsap, tol=1e-6, maxiter=300))
        with mesh:
            x, its, res = step(
                band_p.astype(jnp.float32), b_p.astype(jnp.float32),
                parts["d"], parts["e"], parts["f"],
                parts["b_next"], parts["c_prev"],
            )
        err = np.linalg.norm(np.asarray(x)[:n] - xstar) / np.linalg.norm(xstar)
        print(
            f"  SaP-{variant}: P={ndev*2} partitions  iters={float(its):5.2f}"
            f"  relerr={err:.2e}"
        )

    # single-device lifecycle reference: factor once, reuse the handle
    fac = factor(
        plan_banded(
            jnp.asarray(band, jnp.float32),
            SaPOptions(p=8, variant="C", tol=1e-6, maxiter=300),
        )
    )
    res = fac.solve(jnp.asarray(b, jnp.float32))
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    print(f"  lifecycle reference (1 device): iters={float(res.iterations):5.2f}"
          f"  relerr={err:.2e}")
    print("distributed solve OK (preconditioner comms: neighbor ppermute only)")


if __name__ == "__main__":
    main()
