"""Distributed SaP solve across a device mesh (the paper's technique as a
first-class distributed workload; partitions span every mesh axis).

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        PYTHONPATH=src python examples/distributed_solve.py
"""

import os
import sys
from pathlib import Path

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_to_dense, oscillatory_banded, random_banded
from repro.core.distributed import build_dist_sap, solve_step_fn
from repro.launch.mesh import make_test_mesh


def _run(mesh, dsap, band, b):
    band_p, b_p, parts = dsap.shard_band(band, b)
    step = jax.jit(solve_step_fn(dsap, tol=1e-6, maxiter=300))
    with mesh:
        return step(
            band_p.astype(jnp.float32), b_p.astype(jnp.float32),
            parts["d"], parts["e"], parts["f"],
            parts["b_next"], parts["c_prev"],
        )


def main():
    ndev = len(jax.devices())
    mesh = make_test_mesh((2, ndev // 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} ({ndev} devices)")

    n, k = 4096, 12
    band = random_banded(n, k, d=1.0, seed=0)
    dense = np.asarray(band_to_dense(jnp.asarray(band)))
    xstar = np.random.default_rng(0).normal(size=n)
    b = dense @ xstar

    for variant in ("C", "D", "E"):
        dsap = build_dist_sap(mesh, n, k, variant=variant, p_per_device=2)
        res = _run(mesh, dsap, band, b)
        err = np.linalg.norm(np.asarray(res.x)[:n] - xstar) / np.linalg.norm(xstar)
        print(
            f"  SaP-{variant}: P={ndev*2} partitions"
            f"  iters={float(res.iterations):5.2f}  relerr={err:.2e}"
            f"  converged={bool(res.converged)}"
        )

    # the hard regime (d = 0.5, non-decaying spikes): truncation breaks
    # down; "auto" estimates d from shard-local rows and picks the exact
    # coupling, whose reduced chain is swept by distributed cyclic
    # reduction in ~log2(P) ppermute rounds -- never gathered.
    band_h = oscillatory_banded(n, k, d=0.5, seed=0)
    dense_h = np.asarray(band_to_dense(jnp.asarray(band_h)))
    b_h = dense_h @ xstar
    dsap = build_dist_sap(mesh, n, k, variant="auto", p_per_device=2,
                          band=band_h)
    res = _run(mesh, dsap, band_h, b_h)
    err = np.linalg.norm(np.asarray(res.x)[:n] - xstar) / np.linalg.norm(xstar)
    print(
        f"  SaP-auto @ d=0.5 -> {dsap.variant}"
        f" (d_factor={dsap.d_factor:.3f})  iters={float(res.iterations):5.2f}"
        f"  relerr={err:.2e}"
    )

    # single-device lifecycle reference: factor once, reuse the handle
    fac = factor(
        plan_banded(
            jnp.asarray(band, jnp.float32),
            SaPOptions(p=8, variant="C", tol=1e-6, maxiter=300),
        )
    )
    res = fac.solve(jnp.asarray(b, jnp.float32))
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    print(f"  lifecycle reference (1 device): iters={float(res.iterations):5.2f}"
          f"  relerr={err:.2e}")
    print("distributed solve OK (preconditioner comms: neighbor ppermute "
          "+ log-depth shift rounds for variant E)")


if __name__ == "__main__":
    main()
