"""Fleet solves: batched many-system factorization + the SolverEngine.

The paper's target workload is sequences of moderate banded systems
(implicit time integration: one Jacobian reused across many steps, many
independent scenarios in flight).  This example runs that workload two
ways:

1. the batched lifecycle -- ``batch_plan``/``batch_factor`` factor a
   whole fleet in one vmapped pass, ``solve_batch`` solves it in one
   compiled executable;
2. the serving path -- heterogeneous requests through ``SolverEngine``:
   shape-bucketed, identity-padded, with an LRU factorization cache so
   repeated Jacobians skip straight to the Krylov stage.

    PYTHONPATH=src python examples/fleet_solve.py
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.sap_solver import fleet
from repro.core import SaPOptions, batch_factor, batch_plan, factor, plan_banded
from repro.core.banded import band_matvec, random_banded


def batched_lifecycle_demo():
    print("== batched lifecycle: 32 systems, one vmapped factor+solve ==")
    s, n, k = 32, 2048, 8
    opts = SaPOptions(p=8, variant="C", tol=1e-6, maxiter=200)
    bands = [jnp.asarray(random_banded(n, k, d=1.0, seed=i), jnp.float32)
             for i in range(s)]
    rng = np.random.default_rng(0)
    xs = np.stack([rng.normal(size=n) for _ in range(s)])
    bmat = jnp.stack([band_matvec(bands[i], jnp.asarray(xs[i], jnp.float32))
                      for i in range(s)])

    t0 = time.perf_counter()
    for i in range(s):  # the naive way: one lifecycle per system
        factor(plan_banded(bands[i], opts)).solve(bmat[i]).x.block_until_ready()
    t_loop = time.perf_counter() - t0

    bfac = batch_factor(batch_plan(bands, opts))  # warm the jit caches
    res = bfac.solve_batch(bmat)
    t0 = time.perf_counter()
    bfac = batch_factor(batch_plan(bands, opts))
    res = bfac.solve_batch(bmat)
    res.x.block_until_ready()
    t_batched = time.perf_counter() - t0

    err = np.abs(np.asarray(res.x)[:, :n] - xs).max()
    print(f"  python loop   : {t_loop * 1e3:9.1f} ms")
    print(f"  batched       : {t_batched * 1e3:9.1f} ms "
          f"({t_loop / t_batched:.1f}x)  maxerr={err:.1e} "
          f"conv={bool(np.asarray(res.converged).all())}")


def engine_demo():
    print("== SolverEngine: heterogeneous fleet, cached factorizations ==")
    cfg = fleet()
    eng = cfg.to_engine(p=8)
    rng = np.random.default_rng(1)
    # 4 distinct Jacobians of different (N, K), re-solved over 8 "time
    # steps" with fresh right-hand sides: 32 requests, 4 factorizations.
    mats = [np.float32(random_banded(1500 + 700 * i, 8 + 4 * (i % 2),
                                     d=1.1, seed=i))
            for i in range(4)]
    for _ in range(8):
        for band in mats:
            eng.submit_system(band, rng.normal(size=band.shape[0]))
    done = eng.run_until_drained()
    conv = all(r.result.converged for r in done)
    buckets = sorted({r.result.bucket for r in done})
    print(f"  solved={len(done)} conv={conv} steps={eng.stats['steps']}")
    print(f"  factored={eng.stats['factored_systems']} "
          f"cache_hit_rate={eng.cache_hit_rate:.0%} "
          f"throughput={eng.systems_per_second:.1f} sys/s")
    print(f"  compiled buckets (N', K', P): {buckets}")


if __name__ == "__main__":
    batched_lifecycle_demo()
    engine_demo()
