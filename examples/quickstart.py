"""Quickstart: the plan/factor/solve lifecycle of SaP::TPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SaPOptions,
    factor,
    plan,
    plan_banded,
    solve_banded,
    solve_sparse,
)
from repro.core.banded import band_to_dense, random_banded, random_rhs
from repro.core.sparse import random_sparse


def dense_banded_demo():
    print("== dense banded: N=4096, K=16, d=1.0 (paper Sec 4.1) ==")
    n, k = 4096, 16
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=0), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(0).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)

    for variant in ("C", "D"):
        fac = factor(plan_banded(band, SaPOptions(p=8, variant=variant, tol=1e-6)))
        res = fac.solve(b)
        err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
        print(
            f"  SaP-{variant}: iters={float(res.iterations):5.2f}  "
            f"relerr={err:.2e}  converged={bool(res.converged)}"
        )


def amortization_demo():
    print("== factor once, solve many (the lifecycle win) ==")
    n, k, nrhs = 4096, 16, 16
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=2), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xs = np.random.default_rng(2).normal(size=(n, nrhs))
    bmat = jnp.asarray(dense @ xs, jnp.float32)
    opts = SaPOptions(p=8, variant="C", tol=1e-6)

    t0 = time.perf_counter()
    for j in range(nrhs):
        solve_banded(band, bmat[:, j], opts)  # re-plans + re-factors each call
    t_oneshot = time.perf_counter() - t0

    fac = factor(plan_banded(band, opts))  # expensive stages paid once
    jax.block_until_ready(fac.solve_many(bmat).x)  # warm the jit cache
    t0 = time.perf_counter()
    res = fac.solve_many(bmat)
    jax.block_until_ready(res.x)
    t_amortized = time.perf_counter() - t0

    err = np.abs(np.asarray(res.x) - xs).max()
    print(f"  one-shot x{nrhs}:      {t_oneshot*1e3:8.1f} ms")
    print(f"  factor-once x{nrhs}:   {t_amortized*1e3:8.1f} ms "
          f"({t_oneshot/t_amortized:.1f}x, maxerr={err:.1e})")


def sparse_demo():
    print("== sparse: scrambled banded provenance (paper Sec 4.3) ==")
    csr = random_sparse(2000, avg_nnz_per_row=6.0, d=1.2, shuffle=True, seed=1)
    xstar = np.asarray(random_rhs(2000))
    b = csr.to_dense() @ xstar

    pl = plan(csr, SaPOptions(p=8, variant="C", tol=1e-8))
    fac = factor(pl)
    res = fac.solve(jnp.asarray(b, jnp.float32))
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    print(
        f"  K after DB+CM reordering: {pl.info['k_after_reorder']}  "
        f"iters={float(res.iterations):.2f}  relerr={err:.2e}"
    )
    sol2 = solve_sparse(
        csr, b, SaPOptions(p=8, variant="C", tol=1e-8, drop_tol=0.02)
    )
    err2 = np.linalg.norm(sol2.x - xstar) / np.linalg.norm(xstar)
    print(f"  with 2% drop-off: K={sol2.k} iters={sol2.iterations:.2f} "
          f"relerr={err2:.2e}")


if __name__ == "__main__":
    dense_banded_demo()
    amortization_demo()
    sparse_demo()
    print("quickstart OK")
