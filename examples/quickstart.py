"""Quickstart: solve dense banded and sparse systems with SaP::TPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import SaPOptions, solve_banded, solve_sparse
from repro.core.banded import band_to_dense, random_banded, random_rhs
from repro.core.sparse import random_sparse


def dense_banded_demo():
    print("== dense banded: N=4096, K=16, d=1.0 (paper Sec 4.1) ==")
    n, k = 4096, 16
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=0), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(0).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)

    for variant in ("C", "D"):
        sol = solve_banded(
            band, b, SaPOptions(p=8, variant=variant, tol=1e-6)
        )
        err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
        print(
            f"  SaP-{variant}: iters={sol.iterations:5.2f}  "
            f"relerr={err:.2e}  converged={sol.converged}"
        )


def sparse_demo():
    print("== sparse: scrambled banded provenance (paper Sec 4.3) ==")
    csr = random_sparse(2000, avg_nnz_per_row=6.0, d=1.2, shuffle=True, seed=1)
    xstar = np.asarray(random_rhs(2000))
    b = csr.to_dense() @ xstar
    sol = solve_sparse(csr, b, SaPOptions(p=8, variant="C", tol=1e-8))
    err = np.linalg.norm(sol.x - xstar) / np.linalg.norm(xstar)
    print(
        f"  K after DB+CM reordering: {sol.info['k_after_reorder']}  "
        f"iters={sol.iterations:.2f}  relerr={err:.2e}"
    )
    sol2 = solve_sparse(
        csr, b, SaPOptions(p=8, variant="C", tol=1e-8, drop_tol=0.02)
    )
    err2 = np.linalg.norm(sol2.x - xstar) / np.linalg.norm(xstar)
    print(f"  with 2% drop-off: K={sol2.k} iters={sol2.iterations:.2f} "
          f"relerr={err2:.2e}")


if __name__ == "__main__":
    dense_banded_demo()
    sparse_demo()
    print("quickstart OK")
