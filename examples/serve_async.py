"""Async solve serving: futures, priorities, deadlines, live metrics.

The multi-tenant front end over the fleet engine
(:class:`repro.serve.service.AsyncSolverService`): four client threads
submit banded systems with mixed priorities and deadlines and block on
futures, while the background drain thread batches concurrent arrivals
per bucket, routes each batch to its dominance class (d >= 1 solves with
truncated "C", d < 1 with exact "E" + BCR), and sheds work whose
deadline lapsed.  Ends with the serving metrics snapshot.

    PYTHONPATH=src python examples/serve_async.py
"""

import json
import sys
import threading
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.configs.sap_solver import service
from repro.core.banded import oscillatory_banded, random_banded
from repro.serve import Cancelled


def main():
    cfg = service()
    svc = cfg.to_service(p=4)
    print(f"== {cfg.name}: async serving, queue_cap={cfg.queue_cap} ==")

    # 3 dominant Jacobians + 1 oscillatory (d=0.5) one: the service routes
    # them to different per-class solver options from a host-side estimate
    mats = [np.float32(random_banded(400 + 100 * i, 4, d=1.2, seed=i))
            for i in range(3)]
    mats.append(np.float32(oscillatory_banded(512, 4, d=0.5, seed=3)))

    futs, lock = [], threading.Lock()

    def client(cid):
        rng = np.random.default_rng(cid)
        for step in range(6):
            band = mats[(cid + step) % len(mats)]
            fut = svc.submit(
                band,
                rng.normal(size=band.shape[0]).astype(np.float32),
                priority=cid % 2,
                # one client sets an impossible deadline now and then to
                # show shedding; everyone else gets a comfortable one
                deadline_s=0.0 if cid == 3 and step == 5 else 120.0,
                timeout=60,
            )
            with lock:
                futs.append(fut)

    threads = [threading.Thread(target=client, args=(c,)) for c in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    solved = shed = 0
    variants = {}
    for fut in futs:
        out = fut.outcome(timeout=300)
        if isinstance(out, Cancelled):
            shed += 1
        else:
            assert out.converged
            solved += 1
            variants[out.variant] = variants.get(out.variant, 0) + 1
    svc.close()

    snap = svc.snapshot()
    print(f"  futures: {solved} solved, {shed} shed "
          f"(deadline_misses={int(snap['counters']['deadline_misses'])})")
    print(f"  variants served: {variants}  "
          f"(C = dominant class, E = oscillatory class)")
    print(f"  throughput: {snap['derived']['solves_per_second']:.1f} "
          f"solves/s  cache_hit_rate={snap['derived']['cache_hit_rate']:.0%}")
    print("  metrics snapshot (trimmed):")
    trimmed = {
        "counters": snap["counters"],
        "queue_depth": snap["histograms"]["queue_depth"],
        "time_in_queue_s": {
            k: v for k, v in snap["histograms"]["time_in_queue_s"].items()
            if k != "buckets"
        },
    }
    print(json.dumps(trimmed, indent=2, default=str))


if __name__ == "__main__":
    main()
