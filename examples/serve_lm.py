"""Serving demo: batched decode with continuous batching (slot refill).

    PYTHONPATH=src python examples/serve_lm.py [--arch stablelm-1.6b]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config
from repro.models import get_family
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=256)

    reqs = [
        Request(rid=i, prompt=[1 + (i * 7) % 100, 2, 3, 4],
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    for r in reqs:
        engine.submit(r)

    t0 = time.perf_counter()
    ticks = engine.run_until_drained()
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {total_tokens} tokens, "
          f"{ticks} engine ticks, {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
