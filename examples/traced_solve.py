"""Traced end-to-end solve: lifecycle spans -> stage tree + Perfetto trace.

Runs the full pipeline the source paper times stage-by-stage -- DB/CM
reordering, block-LU + SPIKE factorization, BiCGStab(2) iteration -- on a
shuffled sparse system in the non-dominant regime (d < 1, so ``auto``
resolves to variant E and the exact reduced system appears in the trace),
under an active :class:`repro.obs.Tracer`.  Prints the merged stage tree
and the Krylov convergence history, then writes a Chrome/Perfetto
trace_event JSON -- open it at https://ui.perfetto.dev.

    PYTHONPATH=src python examples/traced_solve.py [--smoke] [--out DIR]
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SaPOptions, factor, plan  # noqa: E402
from repro.core.sparse import random_sparse  # noqa: E402
from repro.obs import Tracer, use_tracer  # noqa: E402


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small system (CI smoke job)")
    ap.add_argument("--out", default=".",
                    help="directory for trace.json (default: cwd)")
    args = ap.parse_args(argv)

    n = 400 if args.smoke else 1024
    # d < 1: oscillatory / non-dominant, the regime where truncation fails
    # and the exact reduced system (variant E) must be solved.
    csr = random_sparse(n, avg_nnz_per_row=5.0, d=0.5, shuffle=True, seed=3)
    dense = csr.to_dense()
    xstar = np.random.default_rng(4).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)
    opts = SaPOptions(p=8, variant="auto", tol=1e-8, maxiter=300)

    tracer = Tracer()  # device_sync=True: spans block on device results
    with use_tracer(tracer):
        fac = factor(plan(csr, opts))
        res = fac.solve(b, record_history=True)

    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    hist = np.asarray(res.history)
    track = hist[~np.isnan(hist)]
    print(f"variant={fac.variant}  converged={bool(res.converged)}  "
          f"iters={float(res.iterations):.2f}  relerr={err:.2e}")
    print(f"convergence history ({track.size} sweeps): "
          f"{track[0]:.3e} -> {track[-1]:.3e}")
    print()
    print(tracer.summary())

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    path = tracer.export_chrome(str(out / "trace.json"))
    print(f"\nwrote {path}  (open at https://ui.perfetto.dev)")

    if not bool(res.converged):
        sys.exit(1)


if __name__ == "__main__":
    main()
