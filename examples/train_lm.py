"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch stablelm-1.6b]

Builds a ~100M-param variant of the chosen family (width-reduced from the
assigned config), streams the deterministic synthetic corpus, checkpoints
periodically and survives a --simulate-crash restart.
"""

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config
from repro.data import DataConfig
from repro.models import get_family
from repro.optim import AdamWConfig
from repro.train import TrainConfig, TrainLoop, run_with_restarts


def make_100m(arch: str):
    """~100M-parameter member of the assigned family."""
    cfg = get_config(arch)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-100m",
        n_layers=8,
        d_model=768,
        n_heads=12,
        n_kv_heads=min(cfg.n_kv_heads, 12),
        d_ff=2048,
        vocab=32_768,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        attn_every=2 if cfg.family == "hybrid" else 0,
        compute_dtype="float32",
        remat="none",
        rwkv_head_dim=64,
        ssm_head_dim=64,
        moe_group=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--simulate-crash", action="store_true")
    args = ap.parse_args()

    cfg = make_100m(args.arch)
    fam = get_family(cfg)
    import jax

    n_params = sum(
        int(p.size) for p in jax.tree.leaves(
            jax.eval_shape(lambda: fam.init(cfg, jax.random.PRNGKey(0)))
        )
    )
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    tc = TrainConfig(
        steps=args.steps,
        checkpoint_every=max(args.steps // 5, 25),
        checkpoint_dir=args.ckpt_dir,
        log_every=max(args.steps // 20, 5),
    )
    oc = AdamWConfig(lr=1e-3, warmup_steps=args.steps // 10,
                     total_steps=args.steps)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, noise=0.05)

    fault = None
    if args.simulate_crash:
        fired = {"n": 0}

        def fault(step):
            if step == args.steps // 2 and fired["n"] == 0:
                fired["n"] += 1
                raise RuntimeError("simulated node failure")

    out, restarts = run_with_restarts(
        lambda: TrainLoop(cfg, oc, tc, dc, fault_hook=fault)
    )
    for row in out["log"]:
        mark = " straggler!" if row["straggler"] else ""
        print(
            f"step {row['step']:5d}  loss {row['loss']:.4f}  "
            f"lr {row['lr']:.2e}  {row['step_time_s']*1e3:7.1f} ms{mark}"
        )
    print(f"final loss: {out['final_loss']:.4f}  restarts: {restarts}")


if __name__ == "__main__":
    main()
