"""SaP::TPU — split-and-parallelize linear solvers (Li, Serban, Negrut
2015) rebuilt TPU-native, inside a multi-pod JAX training/inference
framework.  See DESIGN.md for the system inventory."""

__version__ = "0.1.0"
