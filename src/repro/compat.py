"""jax version-compatibility shims shared across the package.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg from ``check_rep`` to ``check_vma``;
dispatch to whichever this jax provides.
"""

from __future__ import annotations

import jax


def axis_size(axis_name):
    """Size of a mapped axis; old jax spells it ``psum(1, axis)``."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
