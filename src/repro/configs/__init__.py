"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``full()`` (the published configuration, verbatim from
the assignment) and ``reduced()`` (a same-family miniature for CPU smoke
tests).  ``sap_solver`` is the paper's own workload (banded linear solve)
and has its own config type.
"""

from __future__ import annotations

from repro.models.api import ModelConfig

from . import (
    deepseek_moe_16b,
    minitron_8b,
    mixtral_8x22b,
    phi3_mini_3_8b,
    phi3_vision_4_2b,
    rwkv6_1_6b,
    sap_solver,
    stablelm_1_6b,
    starcoder2_15b,
    whisper_medium,
    zamba2_2_7b,
)

ARCHS = {
    "rwkv6-1.6b": rwkv6_1_6b,
    "mixtral-8x22b": mixtral_8x22b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "stablelm-1.6b": stablelm_1_6b,
    "minitron-8b": minitron_8b,
    "starcoder2-15b": starcoder2_15b,
    "zamba2-2.7b": zamba2_2_7b,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "whisper-medium": whisper_medium,
}

SOLVER_ARCHS = {"sap-solver": sap_solver}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = ARCHS[name]
    return mod.reduced() if reduced else mod.full()


def arch_names():
    return list(ARCHS)
