"""deepseek-moe-16b -- fine-grained MoE: 2 shared + 64 routed, top-6.
[arXiv:2401.06066; hf]  28L d_model=2048 16H d_ff=1408 vocab=102400."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=102_400,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        expert_sharding="ep",  # 64 experts / 16-way model axis = 4 each
        capacity_factor=1.25,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab=512,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        moe_group=64,
        compute_dtype="float32",
        remat="none",
    )
