"""minitron-8b -- pruned nemotron: squared-ReLU MLP (ungated), GQA kv=8,
huge 256k vocab.  [arXiv:2407.14679; hf]  32L d=4096 32H d_ff=16384."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16_384,
        vocab=256_000,
        act="relu2",
        gated_mlp=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        act="relu2",
        gated_mlp=False,
        compute_dtype="float32",
        remat="none",
    )
