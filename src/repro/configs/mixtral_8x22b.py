"""mixtral-8x22b -- 8 experts top-2, GQA kv=8, sliding-window attention.
[arXiv:2401.04088; hf]  56L d_model=6144 48H d_ff=16384 vocab=32768."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16_384,
        vocab=32_768,
        window=4096,  # SWA per assignment
        n_experts=8,
        top_k=2,
        expert_sharding="tp",  # 8 experts < 16-way model axis -> shard d_ff
        capacity_factor=1.25,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        window=32,
        n_experts=4,
        top_k=2,
        moe_group=64,
        compute_dtype="float32",
        remat="none",
    )
