"""phi3-mini-3.8b -- dense, RoPE, SwiGLU, GQA(kv=32 == MHA).
[arXiv:2404.14219; unverified]  32L d_model=3072 32H d_ff=8192 vocab=32064."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32_064,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        compute_dtype="float32",
        remat="none",
    )
