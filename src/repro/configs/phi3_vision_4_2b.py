"""phi-3-vision-4.2b -- phi3-mini backbone + CLIP patch embeddings (STUB:
input_specs provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]  32L d=3072 32H d_ff=8192."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=32_064,
        n_patches=576,  # 24x24 CLIP-ViT grid (stub frontend)
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-vision-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        n_patches=8,
        compute_dtype="float32",
        remat="none",
    )
