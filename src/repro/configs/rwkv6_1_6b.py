"""rwkv6-1.6b -- Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="rwkv",
        n_layers=24,
        d_model=2048,
        n_heads=32,  # wkv heads = d_model / rwkv_head_dim
        n_kv_heads=32,
        d_ff=7168,
        vocab=65_536,
        rwkv_head_dim=64,
        rwkv_lora=64,
        tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-reduced",
        family="rwkv",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        rwkv_head_dim=16,
        rwkv_lora=8,
        ssm_chunk=16,
        compute_dtype="float32",
        remat="none",
    )
