"""sap-solver -- the paper's own workload as a first-class arch.

Dense banded linear solve A x = b (paper Sec. 4.1) run as a distributed
SaP::TPU job: partitions flattened over every mesh axis, one (or more)
partitions per chip, truncated-SPIKE preconditioner + BiCGStab(2).

Shapes mirror the paper's experiments, scaled to a 256/512-chip mesh:
  * dense_200k  -- N=200,000  K=200  (paper Table 4.1 / 4.2 setting)
  * dense_1m    -- N=1,048,576 K=500 (paper Table 4.3 largest row)
  * dense_4m    -- N=4,194,304 K=200 (beyond-paper scale-out cell)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str
    n: int
    k: int
    variant: str = "C"  # coupled (truncated SPIKE); "D" = decoupled
    p_per_device: int = 1
    d: float = 1.0  # diagonal dominance of the generated test matrix
    tol: float = 1e-8
    maxiter: int = 200
    precond_dtype: str = "float32"  # bfloat16 on TPU = paper's mixed precision


@dataclasses.dataclass(frozen=True)
class SolverShape:
    name: str
    n: int
    k: int


SOLVER_SHAPES = {
    "dense_200k": SolverShape("dense_200k", 200_000, 200),
    "dense_1m": SolverShape("dense_1m", 1_048_576, 500),
    "dense_4m": SolverShape("dense_4m", 4_194_304, 200),
}


def full() -> SolverConfig:
    return SolverConfig(name="sap-solver", n=200_000, k=200)


def reduced() -> SolverConfig:
    return SolverConfig(name="sap-solver-reduced", n=512, k=8, maxiter=50)
