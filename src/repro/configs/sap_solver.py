"""sap-solver -- the paper's own workload as a first-class arch.

Dense banded linear solve A x = b (paper Sec. 4.1) run as a distributed
SaP::TPU job: partitions flattened over every mesh axis, one (or more)
partitions per chip, truncated-SPIKE preconditioner + BiCGStab(2).

Shapes mirror the paper's experiments, scaled to a 256/512-chip mesh:
  * dense_200k  -- N=200,000  K=200  (paper Table 4.1 / 4.2 setting)
  * dense_1m    -- N=1,048,576 K=500 (paper Table 4.3 largest row)
  * dense_4m    -- N=4,194,304 K=200 (beyond-paper scale-out cell)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str
    n: int
    k: int
    # "C" truncated coupled | "D" decoupled | "E" exact reduced interface
    # chain (distributed cyclic reduction) | "auto" (C at d >= 1 else E)
    variant: str = "C"
    # reduced-chain solver for single-device variant E: "chain" | "bcr" |
    # "auto" (bcr once the chain is long enough); the distributed path
    # always runs the log-depth PCR sweep.
    reduced_solver: str = "auto"
    p_per_device: int = 1
    d: float = 1.0  # diagonal dominance of the generated test matrix
    tol: float = 1e-8
    maxiter: int = 200
    precond_dtype: str = "float32"  # bfloat16 on TPU = paper's mixed precision
    # batching knobs (the fleet-serving path: repro.serve.solver_engine).
    # max_batch caps the per-step system batch; fac_cache sizes the LRU of
    # cached factorizations (keyed by matrix fingerprint); bucket_rounding
    # controls how heterogeneous (N, K) requests share compiled shapes
    # ("pow2" = round up to powers of two, "exact" = identical shapes only).
    max_batch: int = 32
    fac_cache: int = 128
    bucket_rounding: str = "pow2"
    # admission / scheduling knobs (the async serving path:
    # repro.serve.service.AsyncSolverService).  queue_cap bounds the
    # pending set before submit blocks or raises QueueFull; deadline_s is
    # the default per-request deadline (None = no deadline); the thrash
    # guard widens bucket_rounding "exact" -> "pow2" when the LRU sheds
    # more than thrash_ratio factorizations per solve over a window of
    # thrash_window solves.
    queue_cap: int = 256
    deadline_s: float | None = None
    thrash_window: int = 32
    thrash_ratio: float = 0.5
    # observability: upper bucket edges for the service's latency-style
    # histograms (time_in_queue_s).  None keeps the library default
    # (repro.serve.metrics.DEFAULT_BOUNDS, 100us..60s); a deployment with
    # a tight latency envelope narrows these to get p99 resolution where
    # its traffic actually lands.
    hist_bounds: tuple[float, ...] | None = None
    # roofline cost accounting (repro.obs.cost): per-bucket flops/bytes/
    # roofline-seconds attribution on the engine, one extra S=1 lowering
    # per bucket the first time it is seen.  Off by default -- serving
    # deployments that dashboard achieved-vs-roofline turn it on.
    cost_accounting: bool = False

    def to_sap_options(self, p: int):
        """Map this workload config onto single-device solver options (the
        lifecycle API path; the distributed path takes the variant knob via
        ``build_dist_sap`` and always sweeps the reduced chain with PCR)."""
        from repro.core.sap import SaPOptions

        return SaPOptions(
            p=p,
            variant=self.variant,
            reduced_solver=self.reduced_solver,
            tol=self.tol,
            maxiter=self.maxiter,
            precond_dtype=self.precond_dtype,
        )

    def to_engine(self, p: int):
        """Build the fleet-serving engine this workload config describes."""
        from repro.serve.solver_engine import SolverEngine

        return SolverEngine(
            self.to_sap_options(p),
            max_batch=self.max_batch,
            cache_size=self.fac_cache,
            rounding=self.bucket_rounding,
            cost_accounting=self.cost_accounting,
        )

    def to_service(self, p: int, start: bool = True):
        """Build the async multi-tenant serving front end (futures +
        background drain + deadline/priority scheduling) this workload
        config describes."""
        from repro.serve.service import AsyncSolverService

        return AsyncSolverService(
            self.to_sap_options(p),
            max_batch=self.max_batch,
            cache_size=self.fac_cache,
            rounding=self.bucket_rounding,
            queue_cap=self.queue_cap,
            default_deadline_s=self.deadline_s,
            thrash_window=self.thrash_window,
            thrash_ratio=self.thrash_ratio,
            hist_bounds=self.hist_bounds,
            cost_accounting=self.cost_accounting,
            start=start,
        )


@dataclasses.dataclass(frozen=True)
class SolverShape:
    name: str
    n: int
    k: int


SOLVER_SHAPES = {
    "dense_200k": SolverShape("dense_200k", 200_000, 200),
    "dense_1m": SolverShape("dense_1m", 1_048_576, 500),
    "dense_4m": SolverShape("dense_4m", 4_194_304, 200),
}


def full() -> SolverConfig:
    return SolverConfig(name="sap-solver", n=200_000, k=200)


def reduced() -> SolverConfig:
    return SolverConfig(name="sap-solver-reduced", n=512, k=8, maxiter=50)


def exact() -> SolverConfig:
    """The non-dominant regime (d < 1) where truncation breaks down and
    the exact reduced system -- solved in log-depth -- is required."""
    return SolverConfig(name="sap-solver-exact", n=200_000, k=200,
                        variant="E", d=0.5)


def service() -> SolverConfig:
    """The multi-tenant serving regime: concurrent clients with mixed
    priorities/deadlines through the async front end; variant is "auto"
    so the per-dominance-class overrides do the routing."""
    return SolverConfig(name="sap-solver-service", n=16_384, k=16,
                        variant="auto", tol=1e-6, max_batch=32,
                        fac_cache=256, queue_cap=512, deadline_s=30.0)


def fleet() -> SolverConfig:
    """The throughput regime: many moderate systems (implicit time
    integration), served batched with cached factorizations."""
    return SolverConfig(name="sap-solver-fleet", n=16_384, k=16,
                        tol=1e-6, max_batch=64, fac_cache=256)
