"""stablelm-1.6b -- dense, RoPE, SwiGLU-style gated MLP.
[hf:stabilityai/stablelm-2-1_6b; unverified]  24L d=2048 32H d_ff=5632
vocab=100352."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab=100_352,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        compute_dtype="float32",
        remat="none",
    )
