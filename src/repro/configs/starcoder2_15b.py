"""starcoder2-15b -- code LM: GQA kv=4, RoPE, sliding window 4096, GELU MLP.
[arXiv:2402.19173; hf]  40L d=6144 48H d_ff=24576 vocab=49152."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24_576,
        vocab=49_152,
        act="gelu",
        gated_mlp=False,
        window=4096,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        act="gelu",
        gated_mlp=False,
        window=32,
        compute_dtype="float32",
        remat="none",
    )
