"""whisper-medium -- encoder-decoder audio backbone; conv frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]  24L d=1024 16H d_ff=4096 vocab=51865."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="encdec",
        n_layers=24,  # decoder layers
        n_enc_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=51_865,
        act="gelu",
        gated_mlp=False,
        norm="ln",
        enc_seq=1500,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        act="gelu",
        gated_mlp=False,
        norm="ln",
        enc_seq=32,
        tie_embeddings=True,
        compute_dtype="float32",
        remat="none",
    )
