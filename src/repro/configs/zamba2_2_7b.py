"""zamba2-2.7b -- hybrid: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]  54L d=2560 32H d_ff=10240 vocab=32000 ssm_state=64."""

from repro.models.api import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10_240,
        vocab=32_000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        conv_width=4,
        attn_every=6,  # shared attention applied after every 6 mamba layers
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-reduced",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        ssm_state=8,
        ssm_head_dim=16,
        ssm_expand=2,
        conv_width=4,
        attn_every=2,
        ssm_chunk=16,
        compute_dtype="float32",
        remat="none",
    )
