"""SaP::TPU core: split-and-parallelize banded/sparse linear solvers.

The paper's contribution (Li, Serban, Negrut 2015) as a composable JAX
module: banded storage, block-tridiagonal factorization, truncated-SPIKE
preconditioning, Krylov solvers, and the DB/CM reordering front end.

Public solver API is the plan/factor/solve lifecycle in ``sap``:
``factor(plan(A, opts)).solve(b)`` -- analysis and factorization run once,
solves are pure JAX and amortize across right-hand sides.
"""

from .banded import (
    BlockTridiag,
    band_matvec,
    band_to_block_tridiag,
    band_to_dense,
    dense_to_band,
    diag_dominance_factor,
    oscillatory_banded,
    pad_banded,
    padded_partition_size,
    random_banded,
    random_rhs,
)
from .block_lu import (
    BTFactors,
    btf_chain,
    btf_ref,
    btf_ul_ref,
    bts_chain,
    bts_ref,
    gj_inverse,
)
from .cyclic_reduction import (
    BCRFactors,
    PCRFactors,
    bcr_factor,
    bcr_solve,
    pcr_factor,
    pcr_solve,
    resolve_reduced_solver,
)
from .batched import (
    BatchedSaPFactorization,
    BatchedSaPPlan,
    batch_factor,
    batch_plan,
    bucket_by_shape,
    bucket_shape,
    index_factorization,
    pad_band_to,
    pad_rhs_to,
    stack_factorizations,
    unpad_solution,
)
from .krylov import KrylovResult, bicgstab2, bicgstab2_many, cg, cg_many
from .operators import BandedOperator, CsrOperator, LinearOperator, as_operator
from .sap import (
    SaPFactorization,
    SaPOptions,
    SaPPlan,
    SaPSolution,
    SaPSolveResult,
    factor,
    plan,
    plan_banded,
    resolve_variant,
    solve_banded,
    solve_sparse,
)
from .spike import SaPPreconditioner, build_preconditioner

__all__ = [
    "BandedOperator",
    "BatchedSaPFactorization",
    "BatchedSaPPlan",
    "BCRFactors",
    "BlockTridiag",
    "BTFactors",
    "CsrOperator",
    "PCRFactors",
    "KrylovResult",
    "LinearOperator",
    "SaPFactorization",
    "SaPOptions",
    "SaPPlan",
    "SaPPreconditioner",
    "SaPSolution",
    "SaPSolveResult",
    "as_operator",
    "band_matvec",
    "band_to_block_tridiag",
    "band_to_dense",
    "batch_factor",
    "batch_plan",
    "bcr_factor",
    "bcr_solve",
    "bucket_by_shape",
    "bucket_shape",
    "bicgstab2",
    "bicgstab2_many",
    "btf_ref",
    "btf_chain",
    "btf_ul_ref",
    "bts_chain",
    "bts_ref",
    "build_preconditioner",
    "cg",
    "cg_many",
    "dense_to_band",
    "diag_dominance_factor",
    "factor",
    "gj_inverse",
    "index_factorization",
    "oscillatory_banded",
    "pad_band_to",
    "pad_banded",
    "pad_rhs_to",
    "padded_partition_size",
    "pcr_factor",
    "pcr_solve",
    "plan",
    "plan_banded",
    "random_banded",
    "random_rhs",
    "resolve_reduced_solver",
    "resolve_variant",
    "solve_banded",
    "solve_sparse",
    "stack_factorizations",
    "unpad_solution",
]
