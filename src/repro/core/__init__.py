"""SaP::TPU core: split-and-parallelize banded/sparse linear solvers.

The paper's contribution (Li, Serban, Negrut 2015) as a composable JAX
module: banded storage, block-tridiagonal factorization, truncated-SPIKE
preconditioning, Krylov solvers, and the DB/CM reordering front end.
"""

from .banded import (
    BlockTridiag,
    band_matvec,
    band_to_block_tridiag,
    band_to_dense,
    dense_to_band,
    pad_banded,
    padded_partition_size,
    random_banded,
    random_rhs,
)
from .block_lu import BTFactors, btf_ref, btf_ul_ref, bts_ref, gj_inverse
from .krylov import KrylovResult, bicgstab2, cg
from .sap import SaPOptions, SaPSolution, solve_banded, solve_sparse
from .spike import SaPPreconditioner, build_preconditioner

__all__ = [
    "BlockTridiag",
    "BTFactors",
    "KrylovResult",
    "SaPOptions",
    "SaPPreconditioner",
    "SaPSolution",
    "band_matvec",
    "band_to_block_tridiag",
    "band_to_dense",
    "bicgstab2",
    "btf_ref",
    "btf_ul_ref",
    "bts_ref",
    "build_preconditioner",
    "cg",
    "dense_to_band",
    "gj_inverse",
    "pad_banded",
    "padded_partition_size",
    "random_banded",
    "random_rhs",
    "solve_banded",
    "solve_sparse",
]
