"""Banded-matrix storage utilities.

Three representations are used throughout SaP::TPU:

1. ``dense``        : plain (N, N) array (tests / tiny problems only).
2. ``band``         : the paper's "tall and thin" storage, shape (N, 2K+1)
                      with ``band[r, j] == A[r, r - K + j]``.  The diagonal
                      lives in column K (paper Sec. 3.1).
3. ``block-tridiag``: the TPU-native form.  Each of the P partitions is a
                      block-tridiagonal matrix with (K x K) blocks, which
                      turns the scalar "window sliding" GPU factorization of
                      the paper into a chain of MXU-friendly (K x K) matmuls.
                      Shapes: D (P, M, K, K) diagonal blocks,
                              E (P, M, K, K) sub-diagonal  (E[:, 0] unused),
                              F (P, M, K, K) super-diagonal (F[:, M-1] unused).

The partition coupling blocks of the paper (B_i super- / C_i sub-coupling,
each K x K) are extracted separately; they drive the spike computation.

All functions are pure JAX (jnp) unless explicitly numpy-only helpers for
test-matrix generation.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# dense <-> band conversions
# ---------------------------------------------------------------------------


def dense_to_band(a: jax.Array, k: int) -> jax.Array:
    """Convert a dense (N, N) banded matrix into (N, 2K+1) band storage."""
    n = a.shape[0]
    cols = jnp.arange(-k, k + 1)

    def row(r):
        idx = r + cols
        valid = (idx >= 0) & (idx < n)
        return jnp.where(valid, a[r, jnp.clip(idx, 0, n - 1)], 0.0)

    return jax.vmap(row)(jnp.arange(n))


def band_to_dense(band: jax.Array) -> jax.Array:
    """Inverse of :func:`dense_to_band`."""
    n, w = band.shape
    k = (w - 1) // 2
    out = jnp.zeros((n, n), band.dtype)
    rows = jnp.arange(n)
    for j in range(w):  # small loop over band width; unrolled at trace time
        cols = rows - k + j
        valid = (cols >= 0) & (cols < n)
        out = out.at[rows, jnp.clip(cols, 0, n - 1)].add(
            jnp.where(valid, band[:, j], 0.0)
        )
    return out


def band_matvec(band: jax.Array, x: jax.Array) -> jax.Array:
    """y = A @ x with A in band storage.  x: (N,) or (N, R)."""
    n, w = band.shape
    k = (w - 1) // 2
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    y = jnp.zeros((n, x.shape[1]), jnp.promote_types(band.dtype, x.dtype))
    for j in range(w):
        shift = j - k  # y[r] += band[r, j] * x[r + shift]
        xs = jnp.roll(x, -shift, axis=0)
        rows = jnp.arange(n) + shift
        valid = ((rows >= 0) & (rows < n))[:, None]
        y = y + jnp.where(valid, band[:, j : j + 1] * xs, 0.0)
    return y[:, 0] if squeeze else y


def diag_dominance_factor(band: jax.Array) -> jax.Array:
    """Degree of diagonal dominance ``d`` of a band-storage matrix.

    Paper Eq. 2.11: the largest ``d`` such that ``|a_ii| >= d * sum_{j!=i}
    |a_ij|`` holds for every row, i.e. ``min_i |a_ii| / sum_{j!=i} |a_ij|``.
    Rows with no off-diagonal mass are infinitely dominant and drop out of
    the minimum (a pure diagonal matrix returns ``inf``).

    The paper's guidance (Sec. 2.1.1): spike truncation is justified for
    d >= 1 (variants C/D); below that the decay argument fails and the
    exact reduced system (variant "E") is the robust choice -- this scalar
    drives the ``variant="auto"`` policy in :mod:`repro.core.sap`.
    """
    w = band.shape[1]
    k = (w - 1) // 2
    diag = jnp.abs(band[:, k])
    off = jnp.sum(jnp.abs(band), axis=1) - diag
    safe = jnp.where(off > 0, off, 1.0)
    ratio = jnp.where(off > 0, diag / safe, jnp.inf)
    return jnp.min(ratio)


# ---------------------------------------------------------------------------
# Partitioning (paper Sec. 3.1: first P_r partitions get floor(N/P)+1 rows)
# ---------------------------------------------------------------------------


def partition_sizes(n: int, p: int) -> np.ndarray:
    base = n // p
    rem = n - p * base
    return np.asarray([base + 1 if i < rem else base for i in range(p)])


def padded_partition_size(n: int, p: int, k: int) -> int:
    """Uniform per-partition row count, padded so K | Ni (identity padding)."""
    ni = -(-n // p)  # ceil
    m = -(-ni // k)
    return m * k


def pad_banded(band: jax.Array, b: jax.Array, n_pad: int) -> Tuple[jax.Array, jax.Array]:
    """Pad system with identity rows so the total size becomes ``n_pad``."""
    n, w = band.shape
    k = (w - 1) // 2
    if n_pad == n:
        return band, b
    extra = n_pad - n
    pad_rows = jnp.zeros((extra, w), band.dtype).at[:, k].set(1.0)
    band_p = jnp.concatenate([band, pad_rows], axis=0)
    if b.ndim == 1:
        b_p = jnp.concatenate([b, jnp.zeros((extra,), b.dtype)])
    else:
        b_p = jnp.concatenate([b, jnp.zeros((extra, b.shape[1]), b.dtype)], axis=0)
    return band_p, b_p


# ---------------------------------------------------------------------------
# band -> block tridiagonal (per partition)
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("d", "e", "f", "b_cpl", "c_cpl"),
    meta_fields=("n",),
)
@dataclasses.dataclass
class BlockTridiag:
    """Block-tridiagonal form of the P partitions + coupling blocks.

    d: (P, M, K, K)   diagonal blocks
    e: (P, M, K, K)   sub-diagonal blocks   (e[:, 0] is zero / unused)
    f: (P, M, K, K)   super-diagonal blocks (f[:, M-1] is zero / unused)
    b_cpl: (P-1, K, K) super coupling block B_i  (rows: bottom of part i,
                        cols: top of part i+1)
    c_cpl: (P-1, K, K) sub coupling block C_{i+1} (rows: top of part i+1,
                        cols: bottom of part i)
    n: original (unpadded) system size
    """

    d: jax.Array
    e: jax.Array
    f: jax.Array
    b_cpl: jax.Array
    c_cpl: jax.Array
    n: int

    @property
    def p(self) -> int:
        return self.d.shape[0]

    @property
    def m(self) -> int:
        return self.d.shape[1]

    @property
    def k(self) -> int:
        return self.d.shape[2]

    @property
    def n_pad(self) -> int:
        return self.p * self.m * self.k

    def tree_flatten(self):  # pragma: no cover - convenience
        return (self.d, self.e, self.f, self.b_cpl, self.c_cpl), self.n


def band_to_block_tridiag(band: jax.Array, k: int, p: int) -> BlockTridiag:
    """Split a banded system into P partitions of block-tridiagonal (K x K)."""
    n = band.shape[0]
    ni = padded_partition_size(n, p, k)
    n_pad = ni * p
    band_p, _ = pad_banded(band, jnp.zeros((n,), band.dtype), n_pad)
    dense_rows = band_p  # (n_pad, 2k+1)
    m = ni // k

    # Scatter band rows into a per-row (3K) window aligned to block columns:
    # row r (global) belongs to block row br = r // k, with offset o = r % k.
    # Window covers columns [br*k - k, br*k + 2k).  Band column j maps to
    # global col c = r - k + j  ->  window index  c - (br*k - k) = o + j.
    w = 2 * k + 1
    win = jnp.zeros((n_pad, 3 * k), band.dtype)
    r = jnp.arange(n_pad)
    o = r % k
    for j in range(w):
        c = r - k + j
        valid = (c >= 0) & (c < n_pad)
        win = win.at[r, o + j].set(jnp.where(valid, dense_rows[:, j], 0.0))

    win = win.reshape(p, m, k, 3 * k)
    e = win[:, :, :, 0:k]
    d = win[:, :, :, k : 2 * k]
    f = win[:, :, :, 2 * k : 3 * k]
    # Zero out the cross-partition pieces: block row 0's sub-diag and block
    # row M-1's super-diag belong to coupling blocks, not to the partition.
    e = e.at[:, 0].set(0.0)
    f = f.at[:, m - 1].set(0.0)

    # Coupling blocks. B_i = A[part i bottom K rows, part i+1 top K cols]
    # which is exactly win[f] of block row (i, M-1); C similarly.
    win_full = win  # (p, m, k, 3k)
    b_cpl = win_full[:-1, m - 1, :, 2 * k : 3 * k]  # (p-1, k, k)
    c_cpl = win_full[1:, 0, :, 0:k]  # (p-1, k, k)
    return BlockTridiag(d=d, e=e, f=f, b_cpl=b_cpl, c_cpl=c_cpl, n=n)


def block_tridiag_to_dense(bt: BlockTridiag) -> jax.Array:
    """Reassemble the full (padded) dense matrix (tests only)."""
    p, m, k = bt.p, bt.m, bt.k
    n = bt.n_pad
    out = np.zeros((n, n), dtype=np.asarray(bt.d).dtype)
    d, e, f = np.asarray(bt.d), np.asarray(bt.e), np.asarray(bt.f)
    for i in range(p):
        off = i * m * k
        for j in range(m):
            r0 = off + j * k
            out[r0 : r0 + k, r0 : r0 + k] = d[i, j]
            if j > 0:
                out[r0 : r0 + k, r0 - k : r0] = e[i, j]
            if j < m - 1:
                out[r0 : r0 + k, r0 + k : r0 + 2 * k] = f[i, j]
    b_cpl, c_cpl = np.asarray(bt.b_cpl), np.asarray(bt.c_cpl)
    for i in range(p - 1):
        rb = (i + 1) * m * k  # first row of partition i+1
        out[rb - k : rb, rb : rb + k] = b_cpl[i]
        out[rb : rb + k, rb - k : rb] = c_cpl[i]
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Test-matrix generators (numpy; mirror the paper's experiments Sec. 4.1)
# ---------------------------------------------------------------------------


def random_banded(
    n: int,
    k: int,
    d: float,
    seed: int = 0,
    dtype=np.float64,
) -> np.ndarray:
    """Random band-storage matrix with degree of diagonal dominance ``d``.

    Off-diagonal entries are U(-1, 1); the diagonal is set so that
    |a_ii| = d * sum_{j != i} |a_ij|  (paper Eq. 2.11, with equality).
    Returns band storage (N, 2K+1).
    """
    rng = np.random.default_rng(seed)
    band = rng.uniform(-1.0, 1.0, size=(n, 2 * k + 1)).astype(dtype)
    # zero out-of-matrix corners
    for j in range(2 * k + 1):
        c = np.arange(n) - k + j
        band[(c < 0) | (c >= n), j] = 0.0
    off = np.abs(band).sum(axis=1) - np.abs(band[:, k])
    sign = np.where(band[:, k] >= 0, 1.0, -1.0)
    band[:, k] = sign * np.maximum(d * off, 1e-3)
    return band


def oscillatory_banded(
    n: int,
    k: int,
    d: float,
    jitter: float = 0.02,
    seed: int = 0,
    dtype=np.float64,
) -> np.ndarray:
    """Band-storage matrix with dominance ``d`` and *non-decaying* spikes.

    :func:`random_banded` draws off-diagonals from U(-1, 1); the random
    signs cancel, so even for d < 1 the partition inverses decay and the
    truncated SPIKE variants stay accurate.  Here every off-diagonal is
    coherently negative (-1 with a small positive jitter), which puts the
    symbol of the matrix near zero: the characteristic roots sit on the
    unit circle and the spikes oscillate without decaying.  For d < 1 this
    is the regime where truncation (variants C/D) genuinely breaks down
    and the exact reduced system (variant "E") is required -- the hard
    scenario of paper Sec. 2.1/4.1.  Returns band storage (N, 2K+1).
    """
    rng = np.random.default_rng(seed)
    band = -(1.0 + jitter * rng.uniform(0.0, 1.0, size=(n, 2 * k + 1)))
    band = band.astype(dtype)
    for j in range(2 * k + 1):
        c = np.arange(n) - k + j
        band[(c < 0) | (c >= n), j] = 0.0
    off = np.abs(band).sum(axis=1) - np.abs(band[:, k])
    band[:, k] = np.maximum(d * off, 1e-3)
    return band


def random_rhs(n: int, seed: int = 1, dtype=np.float64) -> np.ndarray:
    """Paper Sec 4.3.3: entries on a parabola from 1.0 to ~400 back to 1.0."""
    t = np.linspace(-1.0, 1.0, n)
    return (400.0 * (1.0 - t * t) + 1.0).astype(dtype)
