"""Batched many-systems solves: one factorization/solve over a fleet axis.

The lifecycle API (:mod:`repro.core.sap`) amortizes the expensive stages
across right-hand sides of a *single* matrix.  The paper's target
workload, though, is sequences of moderately sized banded systems -- one
per time step, one per scenario, one per user -- and serving such fleets
wants a *system* batch axis: factor S independent systems in one vmapped
device pass and solve them in one compiled executable, instead of S
python-loop round trips.

Two layers live here:

1. **Batched lifecycle** -- :func:`batch_plan` / :func:`batch_factor`
   produce a :class:`BatchedSaPFactorization`: a stacked
   :class:`~repro.core.sap.SaPFactorization` pytree whose data leaves
   carry a leading system axis (built by vmapping the device stages of
   ``sap.factor``), with ``solve_batch`` (one RHS per system, ``(S, N)``)
   and ``solve_batch_many`` (``(S, N, R)``).

2. **Bucketing** -- heterogeneous fleets cannot share a compiled shape.
   :func:`bucket_shape` / :func:`bucket_by_shape` round each system's
   ``(N, K)`` up to a shared bucket (power-of-two rounding by default) and
   :func:`pad_band_to` embeds a system *exactly* into the bucket shape.

   The N axis pads with decoupled identity rows.  The K axis is the
   subtle one: zero side columns are *algebraically* exact but
   *structurally* singular -- a K' > K band whose outer diagonals are
   exactly zero has strictly-triangular coupling blocks, so the K'-blocked
   pivots of the block LU become ill-conditioned and the "exact" variant E
   preconditioner silently loses digits (the converged-but-wrong failure
   of ROADMAP/PR 6).  When K widens, :func:`pad_band_to` therefore
   *interleaves* identity rows instead: every K original rows are followed
   by K' - K identity slots, which makes the padded matrix a symmetric
   permutation of ``blkdiag(A, I)`` whose K'-blocked pivots are exactly
   ``(original KxK pivot) (+) I`` -- same conditioning as the unpadded
   factorization, bit-for-bit.  The row permutation
   (:func:`pad_permutation`) rides the factorization's ``b_perm`` /
   ``x_perm`` slots, so callers keep the contiguous contract: RHS in as
   ``[b; 0]``, solution out as ``[x; 0]``.

The per-system factorizations inside a batch are slicable
(:func:`index_factorization`) and re-stackable
(:func:`stack_factorizations`), which is what the serving engine
(:mod:`repro.serve.solver_engine`) uses to mix cached and freshly
factored systems inside one batched solve.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from functools import lru_cache, partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.cost import timed_compile
from ..obs.trace import span
from .banded import band_to_block_tridiag, diag_dominance_factor
from .operators import BandedOperator
from .sap import (
    SaPFactorization,
    SaPOptions,
    SaPSolveResult,
    _convergence_summary,
    _precond_dtype,
    _solve_impl,
    resolve_solver,
    resolve_variant,
)
from .spike import build_preconditioner


# ---------------------------------------------------------------------------
# Bucketing: shared compiled shapes for heterogeneous fleets
# ---------------------------------------------------------------------------


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length()


def interleaved_rows(n: int, k: int, k_pad: int) -> int:
    """Rows the structurally exact K-widened embedding needs.

    Widening K to K' > K interleaves K' - K identity rows after every K
    original rows (see :func:`pad_band_to`), so N grows to
    ``ceil(N / K) * K'``.  No widening (or K = 0, where there are no
    couplings to keep well-conditioned) needs no extra rows.
    """
    if k <= 0 or k_pad <= k:
        return n
    return -(-n // k) * k_pad


def bucket_shape(
    n: int, k: int, p: int, rounding: str = "pow2"
) -> Tuple[int, int, int]:
    """Round a system's ``(N, K)`` up to its bucket ``(N', K', P)``.

    ``rounding="pow2"`` keeps the number of distinct compiled shapes
    logarithmic in the size spread (at most ~2x padding waste);
    ``"exact"`` buckets only identical shapes together.  ``K'`` is never
    rounded below 2 so degenerate K=0/1 systems still form K x K blocks.
    When ``K' > K`` the bucket's ``N'`` also covers the interleaved
    identity-row embedding (:func:`interleaved_rows`) so the K-widening
    stays structurally exact.
    """
    if rounding == "pow2":
        kb = max(_next_pow2(k), 2)
    elif rounding == "exact":
        kb = max(k, 2)
    else:
        raise ValueError(f"unknown bucket rounding {rounding!r}")
    n_eff = interleaved_rows(n, k, kb)
    if rounding == "pow2":
        nb = max(_next_pow2(n_eff), p * kb)
    else:
        nb = max(n_eff, p * kb)
    # block-tridiag partitioning pads to P * M * K' anyway; absorb that
    # padding into the bucket so the bucket key IS the compiled shape.
    nb = _round_up(nb, p * kb)
    return nb, kb, p


def bucket_by_shape(
    shapes: Sequence[Tuple[int, int]], p: int, rounding: str = "pow2"
) -> dict:
    """Group systems by shared compiled shape.

    ``shapes`` is a sequence of per-system ``(N, K)``; returns an ordered
    ``{(N', K', P): [indices...]}`` mapping (insertion order = first
    occurrence, so callers can drain buckets deterministically).
    """
    buckets: dict = {}
    for i, (n, k) in enumerate(shapes):
        buckets.setdefault(bucket_shape(n, k, p, rounding), []).append(i)
    return buckets


def _pad_positions(n: int, k: int, k_pad: int) -> np.ndarray:
    """Interleaved position of original row t: chunk ``t // k`` of K rows
    starts at ``(t // k) * K'`` in the padded frame."""
    t = np.arange(n)
    return (t // k) * k_pad + (t % k)


def pad_permutation(
    n: int, k: int, n_pad: int, k_pad: int
) -> Optional[np.ndarray]:
    """Contiguous -> padded row map of the bucket embedding, or None.

    Returns ``perm`` (int32, length N') such that for a padded-frame
    vector ``v``, ``v[perm]`` is the contiguous-frame vector: original
    row ``t < N`` lives at padded row ``perm[t]``, identity pad slots
    occupy ``perm[N:]``.  None when the embedding is contiguous (no
    K-widening, K = 0, or not enough rows to interleave), i.e. original
    rows simply occupy the first N slots.
    """
    if k <= 0 or k_pad <= k or interleaved_rows(n, k, k_pad) > n_pad:
        return None
    pos = _pad_positions(n, k, k_pad)
    pad_slots = np.setdiff1d(np.arange(n_pad), pos)
    return np.concatenate([pos, pad_slots]).astype(np.int32)


def _pad_band_interleaved(
    band: jax.Array, n_pad: int, k_pad: int
) -> jax.Array:
    """K-widening embedding that preserves block conditioning exactly.

    Insert ``K' - K`` identity rows after every K original rows.  The
    resulting (N', 2K'+1) band is a symmetric permutation of
    ``blkdiag(A, I)``: every K'xK' partition block of the block-tridiag
    factorization is (an original KxK block) (+) (an identity slot), so
    pivots, spikes, and the reduced interface system have *identical*
    conditioning to the unpadded factorization -- unlike zero side
    columns, which make the widened coupling blocks strictly triangular
    (structurally singular) and poison the f32 block-pivot inverses.
    """
    band = jnp.asarray(band)
    n, w = band.shape
    k = (w - 1) // 2
    pos = _pad_positions(n, k, k_pad)
    t = np.arange(n)
    rows, cols, src_t, src_j = [], [], [], []
    for j in range(w):
        c = t + (j - k)
        valid = (c >= 0) & (c < n)
        tv = t[valid]
        # |pos[c] - pos[t]| <= K' for |c - t| <= K: same or adjacent chunk
        off = pos[c[valid]] - pos[tv]
        rows.append(pos[tv])
        cols.append(k_pad + off)
        src_t.append(tv)
        src_j.append(np.full(tv.shape, j))
    out = jnp.zeros((n_pad, 2 * k_pad + 1), band.dtype)
    out = out.at[:, k_pad].set(1.0)  # identity everywhere ...
    return out.at[np.concatenate(rows), np.concatenate(cols)].set(
        band[np.concatenate(src_t), np.concatenate(src_j)]
    )  # ... original entries overwrite their slots (targets are unique)


def pad_band_to(band: jax.Array, n_pad: int, k_pad: int) -> jax.Array:
    """Embed an (N, 2K+1) band exactly into bucket shape (N', 2K'+1).

    When K widens (``K' > K > 0``) and the bucket has room
    (``interleaved_rows(N, K, K') <= N'``, guaranteed for buckets from
    :func:`bucket_shape`), the embedding interleaves identity rows so the
    padded matrix is a symmetric permutation of ``blkdiag(A, I)`` --
    structurally exact, same conditioning as unpadded (see
    :func:`_pad_band_interleaved`); recover the row order with
    :func:`pad_permutation` (``batch_factor`` wires it into the
    factorization's ``b_perm`` / ``x_perm`` automatically).

    Otherwise the embedding is contiguous: zero side columns for the
    added diagonals, identity rows appended below.  That form is
    algebraically exact too, but a widened K leaves structurally singular
    coupling blocks whose boosted pivots degrade the preconditioner --
    only acceptable when K does not widen.
    """
    band = jnp.asarray(band)
    n, w = band.shape
    k = (w - 1) // 2
    if k_pad < k or n_pad < n:
        raise ValueError(
            f"bucket shape (N'={n_pad}, K'={k_pad}) smaller than system "
            f"(N={n}, K={k})"
        )
    if pad_permutation(n, k, n_pad, k_pad) is not None:
        return _pad_band_interleaved(band, n_pad, k_pad)
    if k_pad != k:
        side = jnp.zeros((n, k_pad - k), band.dtype)
        band = jnp.concatenate([side, band, side], axis=1)
    if n_pad != n:
        rows = jnp.zeros((n_pad - n, 2 * k_pad + 1), band.dtype)
        rows = rows.at[:, k_pad].set(1.0)
        band = jnp.concatenate([band, rows], axis=0)
    return band


def band_effective_k(band) -> int:
    """True half-bandwidth: stored K minus exactly-zero outer diagonals.

    A band *stored* wider than its couplings (e.g. a K=3 matrix in K=4
    storage) reproduces the structurally-singular zero-diagonal problem
    no matter how it is bucketed; trimming to the effective K first
    (:func:`trim_band_to_effective`) restores the exact embedding.  Host-
    side (numpy) -- used on the serving escalation path.
    """
    a = np.asarray(band)
    k = (a.shape[1] - 1) // 2
    ke = k
    while ke > 0 and not (np.any(a[:, k - ke]) or np.any(a[:, k + ke])):
        ke -= 1
    return ke


def trim_band_to_effective(band) -> np.ndarray:
    """Drop exactly-zero outer diagonal pairs from band storage."""
    a = np.asarray(band)
    k = (a.shape[1] - 1) // 2
    ke = band_effective_k(a)
    return a if ke == k else a[:, k - ke: k + ke + 1]


def pad_rhs_to(b: jax.Array, n_pad: int) -> jax.Array:
    """Zero-pad a (N,) or (N, R) right-hand side to the bucket length."""
    b = jnp.asarray(b)
    if b.shape[0] == n_pad:
        return b
    pad = jnp.zeros((n_pad - b.shape[0],) + b.shape[1:], b.dtype)
    return jnp.concatenate([b, pad], axis=0)


# ---------------------------------------------------------------------------
# Stage 1: batch_plan (stack a fleet into one bucket shape)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchedSaPPlan:
    """Host-side plan for a fleet of banded systems sharing one bucket.

    bands   : (S, N', 2K'+1) stacked (padded) band storage
    k, n    : bucket half-bandwidth K' and size N'
    orig_ns : per-system original sizes (for un-padding results)
    orig_ks : per-system original half-bandwidths (for the interleaved
              K-widening permutations; empty = assume no widening)
    opts    : solver options shared by the whole batch
    """

    bands: jax.Array
    k: int
    n: int
    orig_ns: Tuple[int, ...]
    opts: SaPOptions
    orig_ks: Tuple[int, ...] = ()

    @property
    def s(self) -> int:
        """Number of systems in the batch."""
        return self.bands.shape[0]


def batch_plan(
    bands: Sequence[jax.Array] | jax.Array,
    opts: Optional[SaPOptions] = None,
    rounding: str = "pow2",
) -> BatchedSaPPlan:
    """Plan a fleet of banded systems as ONE stacked, bucket-padded batch.

    ``bands`` is either an already-stacked (S, N, 2K+1) array (uniform
    fleet) or a sequence of per-system (N_i, 2K_i+1) bands (heterogeneous
    fleet).  All systems are padded to the single bucket covering the
    largest ``(N, K)`` in the fleet -- callers that want *multiple*
    compiled shapes split the fleet with :func:`bucket_by_shape` first
    (the serving engine does exactly that).
    """
    opts = opts or SaPOptions()
    if isinstance(bands, (jnp.ndarray, np.ndarray)) and np.ndim(bands) == 3:
        stacked = jnp.asarray(bands)
        s, n, w = stacked.shape
        k = (w - 1) // 2
        nb, kb, _ = bucket_shape(n, k, opts.p, rounding)
        orig_ns = (n,) * s
        if (nb, kb) != (n, k):
            stacked = jnp.stack([pad_band_to(bd, nb, kb) for bd in stacked])
        return BatchedSaPPlan(
            bands=stacked, k=kb, n=nb, orig_ns=orig_ns, opts=opts,
            orig_ks=(k,) * s,
        )

    bands = [jnp.asarray(bd) for bd in bands]
    if not bands:
        raise ValueError("batch_plan needs at least one system")
    shapes = [(bd.shape[0], (bd.shape[1] - 1) // 2) for bd in bands]
    nb = max(bucket_shape(n, k, opts.p, rounding)[0] for n, k in shapes)
    kb = max(bucket_shape(n, k, opts.p, rounding)[1] for n, k in shapes)
    # the fleet bucket's K' may exceed a member's own bucket K', widening
    # its interleaved embedding beyond its own N' -- grow N' to cover the
    # worst member so every embedding stays structurally exact.
    need = max(interleaved_rows(n, k, kb) for n, k in shapes)
    if rounding == "pow2":
        nb = max(nb, _next_pow2(need))
    else:
        nb = max(nb, need)
    nb = _round_up(nb, opts.p * kb)  # one bucket for the whole fleet
    stacked = jnp.stack([pad_band_to(bd, nb, kb) for bd in bands])
    return BatchedSaPPlan(
        bands=stacked,
        k=kb,
        n=nb,
        orig_ns=tuple(n for n, _ in shapes),
        opts=opts,
        orig_ks=tuple(k for _, k in shapes),
    )


# ---------------------------------------------------------------------------
# Stage 2: batch_factor (vmapped device stages; one compiled factor pass)
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("fac",),
    meta_fields=("s", "orig_ns"),
)
@dataclasses.dataclass(eq=False)
class BatchedSaPFactorization:
    """S independent SaP factorizations stacked over a leading system axis.

    ``fac`` is a :class:`~repro.core.sap.SaPFactorization` whose *data*
    leaves (band, preconditioner factors, d_factor) carry a leading
    ``(S, ...)`` axis while the meta fields (bucket shape, tolerances)
    are shared -- exactly the layout ``jax.vmap`` wants, so the whole
    batch solves inside one compiled executable.
    """

    fac: SaPFactorization
    s: int
    orig_ns: Tuple[int, ...]

    @property
    def n(self) -> int:
        """Padded per-system size shared by the whole batch."""
        return self.fac.n

    @property
    def k(self) -> int:
        """Padded half-bandwidth shared by the whole batch."""
        return self.fac.k

    @property
    def variant(self) -> str:
        """Resolved SaP variant shared by the whole batch."""
        return self.fac.variant

    def solve_batch(
        self, b: jax.Array, record_history: bool = False
    ) -> SaPSolveResult:
        """Solve system i against RHS i: b (S, N') -> x (S, N')."""
        b = jnp.asarray(b)
        if b.ndim != 2 or b.shape != (self.s, self.n):
            raise ValueError(
                f"solve_batch expects one RHS per system, shape "
                f"({self.s}, {self.n}); got {b.shape}"
            )
        with span(
            "krylov", s=self.s, n=self.n, k=self.k, variant=self.variant
        ) as sp:
            res = sp.sync(_solve_batch(self.fac, b, record_history=record_history))
        if sp:
            sp.annotate(convergence=_convergence_summary(res))
        return res

    def solve_batch_many(
        self, b: jax.Array, record_history: bool = False
    ) -> SaPSolveResult:
        """Solve R RHS per system: b (S, N', R) -> x (S, N', R)."""
        b = jnp.asarray(b)
        if b.ndim != 3 or b.shape[:2] != (self.s, self.n):
            raise ValueError(
                f"solve_batch_many expects shape ({self.s}, {self.n}, R); "
                f"got {b.shape}"
            )
        with span(
            "krylov",
            s=self.s,
            n=self.n,
            k=self.k,
            variant=self.variant,
            nrhs=int(b.shape[2]),
        ) as sp:
            res = sp.sync(
                _solve_batch_many(self.fac, b, record_history=record_history)
            )
        if sp:
            sp.annotate(convergence=_convergence_summary(res))
        return res


@partial(jax.jit, static_argnames=("record_history",))
def _solve_batch(
    fac: SaPFactorization, b: jax.Array, record_history: bool = False
) -> SaPSolveResult:
    # every data leaf of ``fac`` carries the system axis: plain vmap.
    return jax.vmap(lambda f, bi: _solve_impl(f, bi, record_history))(fac, b)


@partial(jax.jit, static_argnames=("record_history",))
def _solve_batch_many(
    fac: SaPFactorization, b: jax.Array, record_history: bool = False
) -> SaPSolveResult:
    inner_axes = SaPSolveResult(
        x=1, iterations=0, resnorm=0, converged=0, true_resnorm=0,
        d_factor=None,
        history=0 if record_history else None,
    )

    def one_system(f, bm):
        return jax.vmap(
            lambda bi: _solve_impl(f, bi, record_history),
            in_axes=1,
            out_axes=inner_axes,
        )(bm)

    return jax.vmap(one_system)(fac, b)


def _factor_key(opts: SaPOptions) -> tuple:
    """The options that actually reach the factor stages -- tolerances and
    Krylov knobs deliberately excluded so they never force a re-trace."""
    return (
        opts.boost_eps,
        opts.precond_dtype,
        opts.reduced_solver,
        opts.fused_factor,
    )


@lru_cache(maxsize=64)
def _factor_stages_fn(k: int, p: int, variant: str, opts_key: tuple):
    """Jitted, vmapped device stages of ``sap.factor`` for one bucket shape.

    Cached per (bucket, variant, factor-relevant options) so the serving
    engine's repeated ``batch_factor`` calls hit the same traced
    executable instead of re-tracing every step.
    """
    boost_eps, precond_dtype, reduced_solver, fused = opts_key
    pdt = _precond_dtype(SaPOptions(precond_dtype=precond_dtype))

    def stages(band):
        d_factor = diag_dominance_factor(band)
        bt = band_to_block_tridiag(band, max(k, 1), p)
        pc = build_preconditioner(
            bt,
            variant=variant,
            boost_eps=boost_eps,
            precond_dtype=pdt,
            reduced_solver=reduced_solver,
            fused=fused,
        )
        return pc, d_factor

    return jax.jit(jax.vmap(stages))


# AOT-compiled factor-stage executables, keyed by (bucket, variant, factor
# options, exact input aval).  One compile per key serves execution
# (batch_factor), the compile-telemetry counters, AND the cost observatory
# (repro.obs.cost reads flops/bytes off the same executable via
# cost_analysis() / as_text()) -- a jit-path re-trace would pay the
# compile twice.  Bounded like _factor_stages_fn; evicted executables
# simply recompile on next use.
_STAGES_EXEC: OrderedDict = OrderedDict()
_STAGES_EXEC_LOCK = threading.Lock()
_STAGES_EXEC_CAP = 64


def factor_stages_compiled(k: int, p: int, variant: str, opts_key: tuple,
                           bands_aval):
    """AOT-compiled vmapped factor stages for one exact batch shape.

    ``bands_aval`` is anything with ``.shape``/``.dtype`` for the stacked
    (S, N', 2K'+1) bands -- a concrete array or a
    ``jax.ShapeDtypeStruct``.  Compile misses are counted and spanned via
    :func:`repro.obs.cost.timed_compile` under the ``factor.batch``
    label.
    """
    akey = (tuple(bands_aval.shape), jnp.dtype(bands_aval.dtype).name)
    ckey = (k, p, variant, opts_key, akey)
    with _STAGES_EXEC_LOCK:
        hit = _STAGES_EXEC.get(ckey)
        if hit is not None:
            _STAGES_EXEC.move_to_end(ckey)
            return hit
    stages = _factor_stages_fn(k, p, variant, opts_key)
    struct = jax.ShapeDtypeStruct(tuple(bands_aval.shape),
                                  jnp.dtype(bands_aval.dtype))
    lowered = stages.lower(struct)
    with timed_compile(
        "factor.batch", bucket=f"{struct.shape[1]}x{k}", s=struct.shape[0]
    ):
        compiled = lowered.compile()
    with _STAGES_EXEC_LOCK:
        # a racing thread may have compiled the same key; first in wins
        hit = _STAGES_EXEC.setdefault(ckey, compiled)
        _STAGES_EXEC.move_to_end(ckey)
        while len(_STAGES_EXEC) > _STAGES_EXEC_CAP:
            _STAGES_EXEC.popitem(last=False)
        return hit


def _stacked_permutations(bpl: BatchedSaPPlan):
    """Per-system contiguous<->padded row maps as stacked (S, N') leaves.

    ``x_perm[i]`` gathers system i's padded-frame solution back to the
    contiguous frame; ``b_perm[i]`` (its inverse) scatters the contiguous
    ``[b; 0]`` RHS into the interleaved frame.  Always materialized --
    identity rows for members that need no interleaving -- so every
    factorization of a bucket shares one pytree structure and the serving
    cache can stack factorizations coming from different plans.
    """
    orig_ks = bpl.orig_ks or (bpl.k,) * bpl.s
    ident = np.arange(bpl.n, dtype=np.int32)
    xs, bs = [], []
    for n, k in zip(bpl.orig_ns, orig_ks):
        perm = pad_permutation(n, k, bpl.n, bpl.k)
        if perm is None:
            xs.append(ident)
            bs.append(ident)
        else:
            xs.append(perm)
            bs.append(np.argsort(perm).astype(np.int32))
    return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(bs))


def batch_factor(bpl: BatchedSaPPlan) -> BatchedSaPFactorization:
    """Factor every system in the batch in one vmapped device pass.

    ``variant="auto"`` resolves once for the whole batch from the *worst*
    (minimum) degree of diagonal dominance, so a single compiled shape
    covers the batch: conservative -- any non-dominant member makes the
    batch use the exact reduced system "E".  (Identity padding rows are
    infinitely dominant and do not perturb the estimate.)
    """
    opts = bpl.opts
    variant = opts.variant
    if variant == "auto":
        d_all = jax.jit(jax.vmap(diag_dominance_factor))(bpl.bands)
        variant = resolve_variant("auto", float(jnp.min(d_all)))
    with span(
        "factor.batch", s=bpl.s, n=bpl.n, k=bpl.k, p=opts.p, variant=variant
    ) as sp:
        compiled = factor_stages_compiled(
            bpl.k, opts.p, variant, _factor_key(opts), bpl.bands
        )
        pcs, d_factors = compiled(jnp.asarray(bpl.bands))
        sp.sync(pcs)
    x_perm, b_perm = _stacked_permutations(bpl)
    fac = SaPFactorization(
        op=BandedOperator(band=bpl.bands, n=bpl.n, k=bpl.k),
        pc=pcs,
        b_perm=b_perm,
        x_perm=x_perm,
        n=bpl.n,
        k=bpl.k,
        tol=opts.tol,
        maxiter=opts.maxiter,
        use_cg=opts.use_cg,
        iter_dtype=opts.iter_dtype,
        solver=resolve_solver(opts.solver, opts.use_cg),
        d_factor=d_factors,
    )
    return BatchedSaPFactorization(fac=fac, s=bpl.s, orig_ns=bpl.orig_ns)


# ---------------------------------------------------------------------------
# Slicing / restacking (the serving engine's cache currency)
# ---------------------------------------------------------------------------


def index_factorization(bfac: BatchedSaPFactorization, i: int) -> SaPFactorization:
    """Extract system ``i`` as a standalone single-system factorization."""
    return jax.tree_util.tree_map(lambda x: x[i], bfac.fac)


def stack_factorizations(
    facs: Sequence[SaPFactorization], orig_ns: Optional[Sequence[int]] = None
) -> BatchedSaPFactorization:
    """Stack single-system factorizations (same bucket shape) into a batch.

    The inverse of :func:`index_factorization`; all handles must share
    their meta (bucket shape, variant, tolerances) -- i.e. come from the
    same bucket -- or the stack is ill-formed and this raises.
    """
    facs = list(facs)
    if not facs:
        raise ValueError("stack_factorizations needs at least one handle")
    treedefs = {jax.tree_util.tree_structure(f) for f in facs}
    if len(treedefs) != 1:
        raise ValueError(
            "cannot stack factorizations from different buckets/variants: "
            f"{len(treedefs)} distinct pytree structures"
        )
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *facs)
    ns = tuple(orig_ns) if orig_ns is not None else (facs[0].n,) * len(facs)
    return BatchedSaPFactorization(fac=stacked, s=len(facs), orig_ns=ns)


def unpad_solution(x: jax.Array, orig_ns: Sequence[int]) -> List[np.ndarray]:
    """Slice a padded (S, N') batch solution back to per-system lengths."""
    xs = np.asarray(x)
    return [xs[i, :n] for i, n in enumerate(orig_ns)]
