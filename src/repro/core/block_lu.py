"""Block-tridiagonal LU / UL factorization -- pure-jnp reference.

This is the TPU adaptation of the paper's dense-banded LU (Sec. 3.1): the
scalar "window sliding" factorization (a GPU warp/thread-block mechanism)
is re-cast as a *block*-tridiagonal factorization with (K x K) blocks, so
every update step is a (K x K) matmul that maps onto the MXU.  For a banded
matrix with half-bandwidth K this block factorization is exact.

    A_i = L_i @ U_i,     L_i unit block-lower-bidiagonal (blocks L_j),
                         U_i block-upper-bidiagonal (diag S_j, super F_j)

    S_0 = D_0
    L_j = E_j @ inv(S_{j-1})          j = 1..M-1
    S_j = D_j - L_j @ F_{j-1}

Pivoting is replaced by *pivot boosting* (paper Sec. 2.2, following
PARDISO): inside the Gauss-Jordan inversion of each S_j, any pivot smaller
than ``boost_eps * max|S_j|`` is boosted to that threshold.

*Structurally* zero rows are exempt from boosting: a row of S_j that is
exactly zero cannot come from rounding -- it is a decoupled slot (identity
padding from shape bucketing, or a band stored wider than its true
bandwidth).  Boosting such a pivot to ``thr`` injects a ``1/thr`` row into
the inverse and poisons every Schur complement downstream; instead the
pivot is treated as exactly 1, so the inverse restricted to those slots is
the identity -- the blkdiag(A, I) semantics the padded embeddings rely on.

The Pallas kernels in ``repro.kernels`` implement exactly these recurrences;
this module doubles as their oracle (re-exported by ``kernels/ref.py``).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

DEFAULT_BOOST = 1e-10


# ---------------------------------------------------------------------------
# Gauss-Jordan inverse with pivot boosting (K x K)
# ---------------------------------------------------------------------------


def gj_inverse(a: jax.Array, boost_eps: float = DEFAULT_BOOST) -> jax.Array:
    """Inverse of a (K, K) block via Gauss-Jordan with pivot boosting.

    Rows of ``a`` that are *exactly* zero (structurally decoupled slots,
    e.g. identity padding) are never boosted: their pivot is taken as 1,
    so the returned inverse acts as the identity on those slots instead of
    a ``1/thr``-sized perturbation.  Elimination never fills a zero row
    (its multiplier column entry is zero), so the test at step ``t`` sees
    the original structure of row ``t``.
    """
    k = a.shape[-1]
    dtype = a.dtype
    scale = jnp.maximum(jnp.max(jnp.abs(a)), jnp.asarray(1e-30, dtype))
    aug = jnp.concatenate([a, jnp.eye(k, dtype=dtype)], axis=1)  # (K, 2K)

    def step(t, aug):
        piv = aug[t, t]
        thr = boost_eps * scale
        struct_zero = jnp.all(aug[t, :k] == 0)
        piv = jnp.where(
            jnp.abs(piv) < thr, jnp.where(piv >= 0, thr, -thr), piv
        )
        piv = jnp.where(struct_zero, jnp.asarray(1.0, dtype), piv)
        # normalize pivot row; treat aug[t, t] as the (possibly boosted) piv,
        # i.e. we factor the perturbed block A + dA (paper Sec. 2.2)
        row = (aug[t] / piv).at[t].set(1.0)
        col = aug[:, t]
        aug = aug - jnp.outer(col, row)
        aug = aug.at[t].set(row)
        return aug

    aug = jax.lax.fori_loop(0, k, step, aug)
    return aug[:, k:]


def gj_solve(a: jax.Array, b: jax.Array, boost_eps: float = DEFAULT_BOOST) -> jax.Array:
    """Solve (K,K) @ x = (K,R) via the boosted inverse (small systems)."""
    return gj_inverse(a, boost_eps) @ b


# ---------------------------------------------------------------------------
# Factorization
# ---------------------------------------------------------------------------


class BTFactors(NamedTuple):
    """Factors of the block-diagonal matrix D = diag(A_1..A_P).

    sinv: (P, M, K, K)  inverses of the block pivots S_j
    l:    (P, M, K, K)  unit-lower block multipliers (l[:, 0] zero)
    f:    (P, M, K, K)  super-diagonal blocks (copied from input)
    """

    sinv: jax.Array
    l: jax.Array
    f: jax.Array


@partial(jax.jit, static_argnames=("boost_eps",))
def btf_ref(
    d: jax.Array, e: jax.Array, f: jax.Array, boost_eps: float = DEFAULT_BOOST
) -> BTFactors:
    """Block-tridiagonal factorization of every partition (vmap over P)."""

    def one_partition(dp, ep, fp):
        m, k, _ = dp.shape

        def step(carry, blocks):
            sinv_prev = carry
            dj, ej, fj_prev = blocks
            lj = ej @ sinv_prev
            sj = dj - lj @ fj_prev
            sinvj = gj_inverse(sj, boost_eps)
            return sinvj, (sinvj, lj)

        s0 = dp[0]
        sinv0 = gj_inverse(s0, boost_eps)
        # blocks j = 1..M-1 paired with F_{j-1}
        xs = (dp[1:], ep[1:], fp[:-1])
        _, (sinv_rest, l_rest) = jax.lax.scan(step, sinv0, xs)
        sinv = jnp.concatenate([sinv0[None], sinv_rest], axis=0)
        l = jnp.concatenate([jnp.zeros_like(l_rest[:1]), l_rest], axis=0)
        return sinv, l

    sinv, l = jax.vmap(one_partition)(d, e, f)
    return BTFactors(sinv=sinv, l=l, f=f)


# ---------------------------------------------------------------------------
# Solve  D @ x = b  (independent per partition)
# ---------------------------------------------------------------------------


@jax.jit
def bts_ref(factors: BTFactors, b: jax.Array) -> jax.Array:
    """Solve with the factors.  b: (P, M, K, R) -> x: (P, M, K, R)."""

    sinv, l, f = factors

    def one_partition(sinvp, lp, fp, bp):
        # forward:  y_j = b_j - L_j y_{j-1}
        def fwd(y_prev, blocks):
            lj, bj = blocks
            yj = bj - lj @ y_prev
            return yj, yj

        y0 = bp[0]
        _, y_rest = jax.lax.scan(fwd, y0, (lp[1:], bp[1:]))
        y = jnp.concatenate([y0[None], y_rest], axis=0)

        # backward: x_{M-1} = Sinv y_{M-1};  x_j = Sinv_j (y_j - F_j x_{j+1})
        def bwd(x_next, blocks):
            sinvj, fj, yj = blocks
            xj = sinvj @ (yj - fj @ x_next)
            return xj, xj

        x_last = sinvp[-1] @ y[-1]
        _, x_rest = jax.lax.scan(
            bwd, x_last, (sinvp[:-1], fp[:-1], y[:-1]), reverse=True
        )
        return jnp.concatenate([x_rest, x_last[None]], axis=0)

    return jax.vmap(one_partition)(sinv, l, f, b)


# ---------------------------------------------------------------------------
# Single-chain convenience (the SaP-E reduced interface system, Sec. 2.1)
# ---------------------------------------------------------------------------


def btf_chain(
    d: jax.Array, e: jax.Array, f: jax.Array, boost_eps: float = DEFAULT_BOOST
) -> BTFactors:
    """Factor a single block-tridiagonal chain (M, K, K).

    Adds the partition axis around :func:`btf_ref` so the same recurrences
    factor *one* chain; used recursively by the SaP-E exact reduced
    interface system (``repro.core.spike``), whose (P-1) coupled interface
    blocks of size 2K form exactly such a chain.  The returned factors keep
    the leading singleton partition axis (pair with :func:`bts_chain`).
    """
    return btf_ref(d[None], e[None], f[None], boost_eps)


def bts_chain(factors: BTFactors, b: jax.Array) -> jax.Array:
    """Solve one factored chain: b (M, K, R) -> x (M, K, R)."""
    return bts_ref(factors, b[None])[0]


# ---------------------------------------------------------------------------
# UL factorization via reversal (for the left-spike top blocks, Sec. 2.1)
# ---------------------------------------------------------------------------


def flip_block_tridiag(
    d: jax.Array, e: jax.Array, f: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Blocks of J A J^T (row+col reversal) per partition.

    Reversal maps block (r, c) -> (M-1-r, M-1-c) and flips each block on
    both axes.  An LU factorization of the reversed matrix is a UL
    factorization of the original (paper Sec. 2.1: the alternative to
    computing the whole left spike W_i).
    """

    def flip2(x):
        return x[..., ::-1, ::-1]

    d_r = flip2(d[:, ::-1])
    # sub-diag of reversed row j is the flipped super-diag of row M-1-j
    e_r = flip2(f[:, ::-1])
    f_r = flip2(e[:, ::-1])
    # fix unused slots
    m = d.shape[1]
    e_r = e_r.at[:, 0].set(0.0)
    f_r = f_r.at[:, m - 1].set(0.0)
    return d_r, e_r, f_r


@partial(jax.jit, static_argnames=("boost_eps",))
def btf_ul_ref(
    d: jax.Array, e: jax.Array, f: jax.Array, boost_eps: float = DEFAULT_BOOST
) -> BTFactors:
    """UL factors == LU factors of the reversed partition."""
    d_r, e_r, f_r = flip_block_tridiag(d, e, f)
    return btf_ref(d_r, e_r, f_r, boost_eps)


# ---------------------------------------------------------------------------
# Fused factor + spike extraction (single ascending pass, Sec. 2.1 + 3.1)
# ---------------------------------------------------------------------------
#
# The SaP preconditioner needs, besides the LU factors of each partition,
# the four corner blocks of the spikes:
#
#   v_bot[i] = Sinv_i[M-1] @ B_i                     (right spike, bottom)
#   v_top[i] = top block of A_i^{-1} [0;..;B_i]      (right spike, top)
#   w_top[i] = top block of A_{i+1}^{-1} [C_{i+1};0;..]   (left spike, top)
#   w_bot[i] = bottom block of the same left spike
#
# The kernel-sequence formulation materializes a full UL factorization
# (w_top) and solves whole K-column spikes through bts (v_top / w_bot),
# each round-tripping (P, M, K, K) intermediates through HBM.  All four
# corners are available from ONE ascending sweep j = 0..M-1 that carries
# four K x K blocks:
#
#   * the LU recurrence (sinv_prev), emitting sinv_j / l_j as usual;
#   * the UL recurrence, i.e. the LU recurrence on the reversed chain
#     (flip_block_tridiag) -- only its carry is kept, no UL factors are
#     ever written;
#   * the left-spike RHS swept forward through LU:  y_0 = C_i,
#     y_j = -l_j y_{j-1}  (the rhs is zero past block 0), so
#     w_bot = sinv_{M-1} y_{M-1} needs no backward substitution;
#   * the right-spike RHS swept forward through UL:  yr_0 = flip(B_i),
#     yr_j = -l^{UL}_j yr_{j-1}, so v_top = flip(sinv^{UL}_{M-1} yr_{M-1}).
#
# ``fused_factor_spike_padded_ref`` is the op-for-op oracle of the Pallas
# megakernel in ``repro.kernels.fused_spike`` (bit-level parity in
# interpret mode); ``fused_factor_spike_ref`` wraps it with the
# (P-1)-interface coupling layout used by ``repro.core.spike``.


class FusedSpikeFactors(NamedTuple):
    """LU factors plus the four spike corner blocks, from one fused pass.

    lu:     factors of diag(A_1..A_P) (identical to :func:`btf_ref`)
    v_bot:  (P-1, K, K)  bottom blocks of the right spikes V_i,  i=0..P-2
    v_top:  (P-1, K, K)  top blocks of the same right spikes
    w_top:  (P-1, K, K)  top blocks of the left spikes W_{i+1}
    w_bot:  (P-1, K, K)  bottom blocks of the same left spikes
    """

    lu: BTFactors
    v_bot: jax.Array
    v_top: jax.Array
    w_top: jax.Array
    w_bot: jax.Array


def _flip2(x: jax.Array) -> jax.Array:
    return x[..., ::-1, ::-1]


def _fliprows(x: jax.Array) -> jax.Array:
    return x[..., ::-1, :]


@partial(jax.jit, static_argnames=("boost_eps",))
def fused_factor_spike_padded_ref(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    bq: jax.Array,
    cq: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
):
    """Fused factor+spike pass on per-partition padded couplings.

    d/e/f: (P, M, K, K); bq/cq: (P, K, K) -- the coupling block *of each
    partition* (``bq[p] = B_p`` or zero for the last partition,
    ``cq[p] = C_p`` or zero for the first), so every partition is an
    independent chain and a batch axis can fold straight into P.

    Returns ``(sinv, l, vb, vt, wt, wb)`` with sinv/l of shape
    (P, M, K, K) and the corners (P, K, K); corner blocks of partitions
    whose coupling is zero come out exactly zero.
    """
    p, m, k, _ = d.shape

    def one_partition(dp, ep, fp, bqp, cqp):
        sinv0 = gj_inverse(dp[0], boost_eps)
        sinv_ul0 = gj_inverse(_flip2(dp[m - 1]), boost_eps)

        def step(carry, blocks):
            sinv_prev, sinv_ul_prev, yw, yv = carry
            dj, ej, fjm1, drj, erj, frm1 = blocks
            lj = ej @ sinv_prev
            sj = dj - lj @ fjm1
            sinvj = gj_inverse(sj, boost_eps)
            yw = -(lj @ yw)
            l_ul = erj @ sinv_ul_prev
            s_ul = drj - l_ul @ frm1
            sinv_ul = gj_inverse(s_ul, boost_eps)
            yv = -(l_ul @ yv)
            return (sinvj, sinv_ul, yw, yv), (sinvj, lj)

        dpr = dp[::-1]
        xs = (
            dp[1:], ep[1:], fp[:-1],
            _flip2(dpr[1:]),          # d_r[j]   = flip2(d[M-1-j])
            _flip2(fp[::-1][1:]),     # e_r[j]   = flip2(f[M-1-j])
            _flip2(ep[::-1][:-1]),    # f_r[j-1] = flip2(e[M-j])
        )
        init = (sinv0, sinv_ul0, cqp, _fliprows(bqp))
        (sinv_l, sinv_ul_l, yw_l, yv_l), (sinv_rest, l_rest) = jax.lax.scan(
            step, init, xs
        )
        sinv = jnp.concatenate([sinv0[None], sinv_rest], axis=0)
        l = jnp.concatenate([jnp.zeros_like(sinv0)[None], l_rest], axis=0)
        vb = sinv_l @ bqp
        wb = sinv_l @ yw_l
        wt = _fliprows(sinv_ul_l @ _fliprows(cqp))
        vt = _fliprows(sinv_ul_l @ yv_l)
        return sinv, l, vb, vt, wt, wb

    return jax.vmap(one_partition)(d, e, f, bq, cq)


def pad_couplings(
    b_cpl: jax.Array, c_cpl: jax.Array, p: int
) -> Tuple[jax.Array, jax.Array]:
    """(P-1, K, K) interface couplings -> per-partition (P, K, K) layout.

    ``bq[p] = B_p`` (zero for the last partition, which has no right
    neighbor); ``cq[p] = C_p`` (zero for the first).  Zero couplings make
    the corresponding corner blocks exactly zero, so padded slots carry no
    information and slicing recovers the interface layout.
    """
    pad = jnp.zeros(b_cpl.shape[:-3] + (1,) + b_cpl.shape[-2:], b_cpl.dtype)
    bq = jnp.concatenate([b_cpl, pad], axis=-3)
    cq = jnp.concatenate([pad, c_cpl], axis=-3)
    return bq, cq


def fused_factor_spike_ref(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    b_cpl: jax.Array,
    c_cpl: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
) -> FusedSpikeFactors:
    """Fused factor + spike-corner extraction (pure-jnp reference).

    d/e/f: (P, M, K, K) partition blocks; b_cpl/c_cpl: (P-1, K, K)
    interface couplings as in :class:`~repro.core.banded.BlockTridiag`.
    ``lu``, ``v_bot`` and ``w_top`` are bit-identical to the
    btf/UL-sequence formulation (:func:`btf_ref` /
    :func:`btf_ul_ref`); ``v_top`` / ``w_bot`` are algebraically equal to
    the whole-spike bts solves but computed through the UL/LU forward
    carries instead (different rounding).
    """
    p = d.shape[0]
    bq, cq = pad_couplings(b_cpl.astype(d.dtype), c_cpl.astype(d.dtype), p)
    sinv, l, vb, vt, wt, wb = fused_factor_spike_padded_ref(
        d, e, f, bq, cq, boost_eps
    )
    return FusedSpikeFactors(
        lu=BTFactors(sinv=sinv, l=l, f=f),
        v_bot=vb[:-1],
        v_top=vt[:-1],
        w_top=wt[1:],
        w_bot=wb[1:],
    )
