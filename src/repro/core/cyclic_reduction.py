"""Block cyclic reduction (BCR) for block-tridiagonal chains.

The SaP-E exact coupling (``repro.core.spike``, paper Sec. 2.1.1) ends in
a (P-1)-interface block-tridiagonal *chain* of (2K x 2K) blocks.  The
``btf_chain``/``bts_chain`` factorization sweeps that chain sequentially:
O(M) dependent steps, the one part of the preconditioner that does not
parallelize.  Cyclic reduction replaces the sweep with even/odd
elimination:

  level 0:   eliminate the odd-indexed unknowns from the even equations
             (every elimination is independent -> fully parallel),
             leaving a block-tridiagonal chain of half the length;
  level l:   recurse on the survivors;
  level L-1: a single block remains -- invert it;
  back-substitution mirrors the levels in reverse, recovering the odd
             unknowns from their (already solved) even neighbors.

O(log2 M) parallel steps in place of O(M) sequential ones -- the same
interface-system strategy that makes sub-structuring methods scale across
GPUs (Cheik Ahamed & Magoules, arXiv:2108.13162) and that parallel
triangular-solve work identifies as the key to beating level-by-level
sweeps (Li, arXiv:1710.04985).

Eliminating odd unknown x_j (j odd) via its own equation

    x_j = inv(D_j) (b_j - E_j x_{j-1} - F_j x_{j+1})

and substituting into the even equations j = 2i gives the level-(l+1)
chain over the even unknowns:

    lo_i  = E_{2i} inv(D_{2i-1})          hi_i = F_{2i} inv(D_{2i+1})
    D'_i  = D_{2i} - lo_i F_{2i-1} - hi_i E_{2i+1}
    E'_i  = -lo_i E_{2i-1}                F'_i = -hi_i F_{2i+1}
    b'_i  = b_{2i} - lo_i b_{2i-1} - hi_i b_{2i+1}

Chains are padded to a power of two with decoupled identity blocks
(D = I, E = F = 0, b = 0), so non-power-of-two lengths work unchanged.

Two factored forms live here:

* :func:`bcr_factor` / :func:`bcr_solve` -- the classic (work-optimal)
  even/odd recursion above, for a chain resident on one device.  The
  Pallas kernel pair in ``repro.kernels.bcr`` implements the same level
  updates; dispatch through ``repro.kernels.ops`` (ref/interpret/pallas).

* :func:`pcr_factor` / :func:`pcr_solve` -- the all-active *parallel*
  cyclic reduction (PCR) form, in which every equation eliminates both
  neighbors at distance s = 2^l each level and no unknown ever goes
  idle.  PCR does O(M log M) work but each level touches only neighbors
  at a fixed stride, which maps 1:1 onto ``ppermute`` shift rounds over a
  device mesh -- ``repro.core.distributed`` uses it for the sharded
  SaP-E reduced sweep (the chain never gathers onto one device).  The
  shift primitive is injected so the identical code runs single-device
  (array shifts, used by the tests as the oracle) and under ``shard_map``
  (collective shifts).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .block_lu import DEFAULT_BOOST, gj_inverse


def _next_pow2(m: int) -> int:
    return 1 if m <= 1 else 1 << (m - 1).bit_length()


def _shift_dn(x: jax.Array, s: int = 1) -> jax.Array:
    """x[i] <- x[i-s] along axis 0; the first s rows get zeros."""
    return jnp.concatenate([jnp.zeros_like(x[:s]), x[:-s]], axis=0)


def _shift_up(x: jax.Array, s: int = 1) -> jax.Array:
    """x[i] <- x[i+s] along axis 0; the last s rows get zeros."""
    return jnp.concatenate([x[s:], jnp.zeros_like(x[:s])], axis=0)


def _vinv(a: jax.Array, boost_eps: float) -> jax.Array:
    return jax.vmap(lambda blk: gj_inverse(blk, boost_eps))(a)


def pad_chain(
    d: jax.Array, e: jax.Array, f: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Zero the unused end blocks and pad with identity blocks to 2^L.

    The padding blocks are decoupled (D = I, E = F = 0): they carry the
    zero solution and never touch the real chain.
    """
    m, k, _ = d.shape
    e = e.at[0].set(0.0)
    f = f.at[m - 1].set(0.0)
    m_pad = _next_pow2(m)
    if m_pad == m:
        return d, e, f
    extra = m_pad - m
    eye = jnp.broadcast_to(jnp.eye(k, dtype=d.dtype), (extra, k, k))
    zero = jnp.zeros((extra, k, k), d.dtype)
    return (
        jnp.concatenate([d, eye], axis=0),
        jnp.concatenate([e, zero], axis=0),
        jnp.concatenate([f, zero], axis=0),
    )


# ---------------------------------------------------------------------------
# Classic even/odd recursion (single chain, log2(M) levels)
# ---------------------------------------------------------------------------


class BCRLevel(NamedTuple):
    """One elimination level; all arrays are (m_l / 2, K, K).

    lo/hi multiply the odd RHS neighbors in the forward reduction;
    a_odd (= inv(D_odd)), e_odd, f_odd drive the back-substitution.
    """

    lo: jax.Array
    hi: jax.Array
    a_odd: jax.Array
    e_odd: jax.Array
    f_odd: jax.Array


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("levels", "root_inv"),
    meta_fields=("m",),
)
@dataclasses.dataclass
class BCRFactors:
    """Log-depth factorization of one block-tridiagonal chain.

    levels[l] holds the level-l elimination blocks (chain length 2^(L-l));
    root_inv is the inverse of the final surviving (K, K) block; ``m`` is
    the true (un-padded) chain length.
    """

    levels: Tuple[BCRLevel, ...]
    root_inv: jax.Array
    m: int

    @property
    def n_levels(self) -> int:
        return len(self.levels)


def bcr_reduce_level_ref(
    d: jax.Array, e: jax.Array, f: jax.Array, boost_eps: float = DEFAULT_BOOST
):
    """One even/odd elimination level (pure jnp; the kernels' oracle).

    Input chain (m, K, K) with m even -> (BCRLevel, d', e', f') of length
    m/2.  All products are batched (K, K) matmuls: MXU-shaped, and every
    one of the m/2 eliminations is independent.
    """
    a_odd = _vinv(d[1::2], boost_eps)
    e_odd, f_odd = e[1::2], f[1::2]
    # E_0 = 0 kills the (clamped) i = 0 down-neighbor terms.
    lo = e[0::2] @ _shift_dn(a_odd)  # E_{2i} inv(D_{2i-1})
    hi = f[0::2] @ a_odd  # F_{2i} inv(D_{2i+1})
    d_next = d[0::2] - lo @ _shift_dn(f_odd) - hi @ e_odd
    e_next = -(lo @ _shift_dn(e_odd))
    f_next = -(hi @ f_odd)
    return BCRLevel(lo=lo, hi=hi, a_odd=a_odd, e_odd=e_odd, f_odd=f_odd), (
        d_next,
        e_next,
        f_next,
    )


def bcr_factor(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
) -> BCRFactors:
    """Factor a block-tridiagonal chain (M, K, K) in log2(M) levels.

    Drop-in alternative to :func:`repro.core.block_lu.btf_chain` (pair
    with :func:`bcr_solve`); ``e[0]`` / ``f[M-1]`` are ignored.  Pivot
    stability comes from the same boosted Gauss-Jordan inversion; like
    the truncated-SPIKE stages, cyclic reduction is elimination without
    pivoting across blocks, which the paper's SaP setting accepts by
    construction (boosting, Sec. 2.2).
    """
    m = d.shape[0]
    d, e, f = pad_chain(d, e, f)
    levels = []
    while d.shape[0] > 1:
        level, (d, e, f) = bcr_reduce_level_ref(d, e, f, boost_eps)
        levels.append(level)
    root_inv = gj_inverse(d[0], boost_eps)
    return BCRFactors(levels=tuple(levels), root_inv=root_inv, m=m)


def bcr_solve(factors: BCRFactors, b: jax.Array) -> jax.Array:
    """Solve one factored chain: b (M, K, R) -> x (M, K, R).

    Forward: log2(M) RHS reductions; root: one (K, K) apply; backward:
    log2(M) interleaving back-substitutions.  Matches
    :func:`repro.core.block_lu.bts_chain` to factorization-dtype accuracy.
    """
    m, k, r = b.shape
    m_pad = _next_pow2(m)
    if m_pad != m:
        b = jnp.concatenate(
            [b, jnp.zeros((m_pad - m, k, r), b.dtype)], axis=0
        )
    saved_odd = []
    for lv in factors.levels:
        b_odd = b[1::2]
        saved_odd.append(b_odd)
        b = b[0::2] - lv.lo @ _shift_dn(b_odd) - lv.hi @ b_odd
    x = (factors.root_inv @ b[0])[None]
    for lv, b_odd in zip(reversed(factors.levels), reversed(saved_odd)):
        # F_odd of the chain tail is zero, killing the clamped up-neighbor.
        x_odd = lv.a_odd @ (b_odd - lv.e_odd @ x - lv.f_odd @ _shift_up(x))
        x = jnp.stack([x, x_odd], axis=1).reshape(2 * x.shape[0], k, r)
    return x[:m]


# ---------------------------------------------------------------------------
# All-active parallel cyclic reduction (the distributed sweep)
# ---------------------------------------------------------------------------


def pcr_n_levels(m: int) -> int:
    """Levels needed to decouple a chain of length m: smallest L with
    2^L >= m (after which every coupling block has been driven to zero)."""
    return max(m - 1, 0).bit_length()


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("alphas", "betas", "dinv"),
    meta_fields=(),
)
@dataclasses.dataclass
class PCRFactors:
    """All-active PCR factorization of a (distributed) chain.

    alphas/betas: (rows, L, K, K) per-level neighbor-elimination blocks
    (row-major so the leading axis shards like every other partition
    array); dinv: (rows, K, K) inverses of the fully decoupled diagonal.
    """

    alphas: jax.Array
    betas: jax.Array
    dinv: jax.Array

    @property
    def n_levels(self) -> int:
        return self.alphas.shape[1]


def pcr_factor(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    n_levels: int,
    shift_dn=None,
    shift_up=None,
    boost_eps: float = DEFAULT_BOOST,
) -> PCRFactors:
    """PCR matrix reduction: every equation eliminates both neighbors at
    stride s = 2^l per level; after ``n_levels`` levels the chain is block
    diagonal.

    ``shift_dn(x, s)`` / ``shift_up(x, s)`` fetch the row s positions
    away (zero fill past the ends).  The defaults operate on a local
    array; ``repro.core.distributed`` injects ``ppermute``-based shifts,
    making each level one neighbor-exchange round over the mesh --
    O(log2 P) rounds total, and the chain never gathers onto one device.

    Rows past the chain end must be decoupled identity padding (see
    :func:`pad_chain`).  Each level inverts the diagonal once and shifts
    the *inverse* both ways; couplings to out-of-range rows are exactly
    zero by induction, so the zero-filled shifted inverse is benign.
    """
    if shift_dn is None:
        shift_dn = _shift_dn
    if shift_up is None:
        shift_up = _shift_up
    rows, k, _ = d.shape
    alphas, betas = [], []
    for lev in range(n_levels):
        s = 1 << lev
        dinv = _vinv(d, boost_eps)
        alpha = e @ shift_dn(dinv, s)
        beta = f @ shift_up(dinv, s)
        d = d - alpha @ shift_dn(f, s) - beta @ shift_up(e, s)
        e_new = -(alpha @ shift_dn(e, s))
        f_new = -(beta @ shift_up(f, s))
        e, f = e_new, f_new
        alphas.append(alpha)
        betas.append(beta)
    stack = lambda xs: (
        jnp.stack(xs, axis=1)
        if xs
        else jnp.zeros((rows, 0, k, k), d.dtype)
    )
    return PCRFactors(
        alphas=stack(alphas), betas=stack(betas), dinv=_vinv(d, boost_eps)
    )


def pcr_solve(
    factors: PCRFactors, b: jax.Array, shift_dn=None, shift_up=None
) -> jax.Array:
    """Apply a PCR factorization to a RHS block b (rows, K, R).

    One shift pair + two batched matmuls per level, then the decoupled
    diagonal apply -- the log-depth replacement for the forward/backward
    chain sweeps.
    """
    if shift_dn is None:
        shift_dn = _shift_dn
    if shift_up is None:
        shift_up = _shift_up
    for lev in range(factors.n_levels):
        s = 1 << lev
        b = (
            b
            - factors.alphas[:, lev] @ shift_dn(b, s)
            - factors.betas[:, lev] @ shift_up(b, s)
        )
    return factors.dinv @ b


def resolve_reduced_solver(reduced_solver: str, m: int) -> str:
    """The ``"auto"`` policy for the SaP-E reduced chain solver.

    Cyclic reduction wins once the chain is long enough for its log-depth
    to beat the sequential sweep's lower constant; short chains (few
    partitions) stay on the ``btf_chain`` sweep.
    """
    if reduced_solver not in ("chain", "bcr", "auto"):
        raise ValueError(f"unknown reduced_solver {reduced_solver!r}")
    if reduced_solver != "auto":
        return reduced_solver
    return "bcr" if m >= 8 else "chain"
