"""Multi-device SaP: partition-per-device solver via shard_map.

The paper's P-way work splitting maps 1:1 onto the TPU mesh: every device
owns ``p_per_device`` partitions; factorization and the two block solves
of the preconditioner are embarrassingly parallel, and communication in
the preconditioner is nearest-neighbor or log-depth:

  variant C (truncated, Sec. 2.1):
    setup:  one ppermute of the left-spike top blocks  W^(t)   (K x K each)
    apply:  one ppermute of g^(t) (down) + one of xt^(b) (up)  (K x R each)
  variant E (exact reduced system, Sec. 2.1.1):
    setup:  one ppermute aligning spike corners + ~log2(P) strided shift
            rounds reducing the (P-1)-interface chain by parallel cyclic
            reduction (``repro.core.cyclic_reduction.pcr_factor``)
    apply:  ~log2(P) shift rounds of (2K x R) blocks -- the chain is
            *never* gathered onto one device.

i.e. O(K^2 log P) bytes per device per apply, independent of N -- the TPU
analogue of the paper's observation that the reduced system is tiny, now
extended to the exact coupling that stays robust below diagonal dominance
d = 1.  The banded matvec for the outer Krylov iteration needs a K-row
halo exchange (two ppermutes).  Everything else (dots, norms in BiCGStab)
is left to pjit/GSPMD at the top level.

Partitions are flattened over *all* mesh axes (tuple-axis collectives), so
the same code runs on the (data, model) single-pod mesh and the
(pod, data, model) multi-pod mesh -- partition boundaries crossing the pod
axis prove the pod-level sharding in the dry-run.

``variant="auto"`` applies the same C-vs-E policy as ``sap.factor()``:
the degree of diagonal dominance (Eq. 2.11) is estimated from shard-local
band rows and reduced over the mesh, picking C at d >= 1 and E below.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from .banded import diag_dominance_factor, pad_banded
from .block_lu import DEFAULT_BOOST, btf_ref, btf_ul_ref, bts_ref, gj_inverse
from .cyclic_reduction import pcr_factor, pcr_n_levels, pcr_solve
from .krylov import bicgstab2
from .sap import SaPSolveResult, resolve_variant


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def n_devices(mesh) -> int:
    return int(mesh.devices.size)


# ---------------------------------------------------------------------------
# Neighbor shifts over the flattened mesh axes (non-cyclic: edges get zeros)
# ---------------------------------------------------------------------------


def _shift_from_next(x, axes):
    """Each device receives the value owned by device (idx+1); last gets 0."""
    n = axis_size(axes)
    perm = [(i + 1, i) for i in range(n - 1)]
    return jax.lax.ppermute(x, axes, perm)


def _shift_from_prev(x, axes):
    """Each device receives the value owned by device (idx-1); first gets 0."""
    n = axis_size(axes)
    perm = [(i, i + 1) for i in range(n - 1)]
    return jax.lax.ppermute(x, axes, perm)


def _from_prev_by(x, dq, axes):
    """Receive the block owned by the device ``dq`` positions before."""
    if dq == 0:
        return x
    n = axis_size(axes)
    perm = [(i, i + dq) for i in range(n - dq)]
    return jax.lax.ppermute(x, axes, perm)


def _from_next_by(x, dq, axes):
    if dq == 0:
        return x
    n = axis_size(axes)
    perm = [(i, i - dq) for i in range(dq, n)]
    return jax.lax.ppermute(x, axes, perm)


def _shift_dn_rows(x, s, axes):
    """Row j of the global (flattened, p_loc rows/device) array receives
    row j - s; rows shifted in past the start are zero.  One stride-s PCR
    neighbor exchange: at most two ppermutes regardless of s."""
    p_loc = x.shape[0]
    q, r = divmod(s, p_loc)
    a = _from_prev_by(x, q, axes)
    if r == 0:
        return a
    b = _from_prev_by(x, q + 1, axes)
    return jnp.concatenate([b[p_loc - r:], a[: p_loc - r]], axis=0)


def _shift_up_rows(x, s, axes):
    """Row j receives row j + s (zeros past the end)."""
    p_loc = x.shape[0]
    q, r = divmod(s, p_loc)
    a = _from_next_by(x, q, axes)
    if r == 0:
        return a
    b = _from_next_by(x, q + 1, axes)
    return jnp.concatenate([a[r:], b[:r]], axis=0)


def _flat_device_index(axes):
    """Row-major flattened index of this device over the mesh axes."""
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * axis_size(ax) + jax.lax.axis_index(ax)
    return idx


# ---------------------------------------------------------------------------
# Distributed preconditioner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistSaP:
    """Compiled distributed solver handle."""

    mesh: object
    k: int
    m: int
    p_local: int
    n_pad: int
    variant: str  # resolved: "C" | "D" | "E"
    variant_requested: str
    matvec: callable
    precond: callable
    factor: callable
    shard_band: callable
    d_factor: Optional[float] = None  # Eq. 2.11 estimate ("auto" only)


def _local_factor_c(d, e, f, b_next, c_prev, boost_eps, axes):
    """Runs per device.  d/e/f: (p_loc, M, K, K); couplings per partition."""
    lu = btf_ref(d, e, f, boost_eps)
    # right-spike bottoms (for interface owned by this partition)
    v_bot = lu.sinv[:, -1] @ b_next  # (p_loc, K, K)
    # left-spike tops of *this* partition (for the interface owned by prev)
    ul = btf_ul_ref(d, e, f, boost_eps)
    w_top = (ul.sinv[:, -1] @ c_prev[..., ::-1, :])[..., ::-1, :]
    # align W^(t) of partition i+1 at interface index i
    w_next = jnp.concatenate(
        [w_top[1:], _shift_from_next(w_top[:1], axes)], axis=0
    )
    eye = jnp.eye(d.shape[-1], dtype=d.dtype)
    rbar = eye - w_next @ v_bot
    rbar_inv = jax.vmap(lambda a: gj_inverse(a, boost_eps))(rbar)
    return lu, v_bot, w_next, rbar_inv


def _local_apply_c(state, b_next, c_prev, rb, axes):
    """Per-device truncated-coupling apply.  rb: (p_loc, M, K, R)."""
    lu, v_bot, w_next, rbar_inv = state
    g = bts_ref(lu, rb)
    g_top, g_bot = g[:, 0], g[:, -1]  # (p_loc, K, R)
    # g^(t) of partition i+1 aligned at interface i
    g_top_next = jnp.concatenate(
        [g_top[1:], _shift_from_next(g_top[:1], axes)], axis=0
    )
    rhs = g_top_next - w_next @ g_bot
    xt_top = rbar_inv @ rhs  # x~ for top of partition i+1
    xt_bot = g_bot - v_bot @ xt_top  # x~ for bottom of partition i
    # partition j needs: bottom corr B_j xt_top[j] (local); top corr
    # C_j xt_bot[j-1] (shift up)
    xt_bot_prev = jnp.concatenate(
        [_shift_from_prev(xt_bot[-1:], axes), xt_bot[:-1]], axis=0
    )
    rb2 = rb.at[:, -1].add(-(b_next @ xt_top))
    rb2 = rb2.at[:, 0].add(-(c_prev @ xt_bot_prev))
    return bts_ref(lu, rb2)


def _local_factor_e(d, e, f, b_next, c_prev, boost_eps, axes, p_total):
    """Sharded exact coupling: assemble this device's (2K x 2K) interface
    blocks from whole-spike corners, then reduce the global chain by
    parallel cyclic reduction -- log2(P) strided shift rounds, no gather.
    """
    lu = btf_ref(d, e, f, boost_eps)
    p_loc, m, k, _ = d.shape
    dtype = d.dtype

    # whole spikes of the local partitions: A_j V_j = [0;..;B_j] (right),
    # A_j W_j = [C_j;0;..] (left); keep the four corner blocks.
    rhs_b = jnp.zeros((p_loc, m, k, k), dtype).at[:, -1].set(b_next)
    v = bts_ref(lu, rhs_b)
    rv_top, rv_bot = v[:, 0], v[:, -1]
    rhs_c = jnp.zeros((p_loc, m, k, k), dtype).at[:, 0].set(c_prev)
    w = bts_ref(lu, rhs_c)
    lw_top, lw_bot = w[:, 0], w[:, -1]

    # interface i lives with partition i and couples y_i = [x_i^b;
    # x_{i+1}^t]: it needs W_{i+1}^t / V_{i+1}^t from partition i+1.
    nxt = lambda x: jnp.concatenate(
        [x[1:], _shift_from_next(x[:1], axes)], axis=0
    )
    lw_top_next = nxt(lw_top)
    rv_top_next = nxt(rv_top)

    eye = jnp.broadcast_to(jnp.eye(k, dtype=dtype), (p_loc, k, k))
    zero = jnp.zeros((p_loc, k, k), dtype)

    def blk2(tl, tr, bl, br):
        top = jnp.concatenate([tl, tr], axis=-1)
        bot = jnp.concatenate([bl, br], axis=-1)
        return jnp.concatenate([top, bot], axis=-2)

    rd = blk2(eye, rv_bot, lw_top_next, eye)
    re = blk2(lw_bot, zero, zero, zero)  # couples to y_{i-1} via W_i^(b)
    rf = blk2(zero, zero, zero, rv_top_next)  # to y_{i+1} via V_{i+1}^(t)

    # The flattened chain has one slot per partition; the last partition's
    # slot is not a real interface -- pad it to a decoupled identity block.
    gidx = _flat_device_index(axes) * p_loc + jnp.arange(p_loc)
    is_pad = (gidx >= p_total - 1)[:, None, None]
    eye2 = jnp.broadcast_to(jnp.eye(2 * k, dtype=dtype), rd.shape)
    rd = jnp.where(is_pad, eye2, rd)
    re = jnp.where(is_pad, 0.0, re)
    rf = jnp.where(is_pad, 0.0, rf)

    shift_dn = lambda x, s: _shift_dn_rows(x, s, axes)
    shift_up = lambda x, s: _shift_up_rows(x, s, axes)
    pcr = pcr_factor(
        rd, re, rf, pcr_n_levels(p_total - 1),
        shift_dn=shift_dn, shift_up=shift_up, boost_eps=boost_eps,
    )
    return lu, pcr


def _local_apply_e(state, b_next, c_prev, rb, axes):
    """Exact-coupling apply: block solve + log-depth reduced sweep +
    corrected block solve (the sharded counterpart of spike._apply_exact)."""
    lu, pcr = state
    k = rb.shape[2]
    g = bts_ref(lu, rb)
    g_top, g_bot = g[:, 0], g[:, -1]  # (p_loc, K, R)
    g_top_next = jnp.concatenate(
        [g_top[1:], _shift_from_next(g_top[:1], axes)], axis=0
    )
    h = jnp.concatenate([g_bot, g_top_next], axis=1)  # (p_loc, 2K, R)
    y = pcr_solve(
        pcr, h,
        shift_dn=lambda x, s: _shift_dn_rows(x, s, axes),
        shift_up=lambda x, s: _shift_up_rows(x, s, axes),
    )
    xt_bot, xt_top = y[:, :k], y[:, k:]  # x_i^(b), x_{i+1}^(t)
    xt_bot_prev = jnp.concatenate(
        [_shift_from_prev(xt_bot[-1:], axes), xt_bot[:-1]], axis=0
    )
    rb2 = rb.at[:, -1].add(-(b_next @ xt_top))
    rb2 = rb2.at[:, 0].add(-(c_prev @ xt_bot_prev))
    return bts_ref(lu, rb2)


def _local_matvec(band_loc, x_loc, k, axes):
    """Banded matvec with K-row halo exchange.  band_loc: (N_loc, 2K+1)."""
    lo = _shift_from_prev(x_loc[-k:], axes)  # prev device's last K entries
    hi = _shift_from_next(x_loc[:k], axes)  # next device's first K entries
    x_ext = jnp.concatenate([lo, x_loc, hi], axis=0)
    n_loc = x_loc.shape[0]
    cols = [band_loc[:, j] * jax.lax.dynamic_slice(x_ext, (j,), (n_loc,))
            for j in range(2 * k + 1)]
    return sum(cols)


# ---------------------------------------------------------------------------
# Sharded dominance estimate (drives variant="auto")
# ---------------------------------------------------------------------------


def dist_diag_dominance_factor(mesh, band_p: jax.Array) -> jax.Array:
    """Degree of diagonal dominance (Eq. 2.11) from shard-local band rows.

    Each device reduces its own rows with :func:`diag_dominance_factor`
    (identity padding rows drop out as infinitely dominant) and the
    per-shard minima are combined with one ``pmin`` over the mesh axes --
    no row ever leaves its device.
    """
    axes = mesh_axes(mesh)

    def local_d(rows):
        return jax.lax.pmin(diag_dominance_factor(rows), axes)

    fn = shard_map(
        local_d,
        mesh=mesh,
        in_specs=(P(axes, None),),
        out_specs=P(),
        check_vma=False,
    )
    return fn(band_p)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


def build_dist_sap(
    mesh,
    n: int,
    k: int,
    variant: str = "C",
    p_per_device: int = 1,
    boost_eps: float = DEFAULT_BOOST,
    precond_dtype=jnp.float32,
    band=None,
):
    """Construct the shard_mapped matvec/precond/factor closures.

    Returns a :class:`DistSaP`; all functions operate on globally-sharded
    arrays and can be jit/lowered on the production mesh.

    ``variant`` is one of "C" (truncated coupling), "D" (decoupled), "E"
    (exact reduced interface chain via distributed cyclic reduction) or
    "auto" -- the same policy as ``sap.factor()``: C when the band is
    diagonally dominant (d >= 1, Eq. 2.11), E below.  "auto" needs the
    band rows to estimate d, so pass ``band`` (host (N, 2K+1) storage);
    the estimate itself runs sharded (:func:`dist_diag_dominance_factor`).
    """
    if variant not in ("C", "D", "E", "auto"):
        raise ValueError(f"unknown distributed SaP variant {variant!r}")
    axes = mesh_axes(mesh)
    ndev = n_devices(mesh)
    p_total = ndev * p_per_device
    ni = -(-n // p_total)  # ceil rows per partition
    m = max(2, -(-ni // k))  # blocks per partition (>= 2 so top != bottom)
    n_pad = p_total * m * k

    variant_requested = variant
    d_factor = None
    if variant == "auto":
        if band is None:
            raise ValueError(
                'variant="auto" needs the band rows to estimate diagonal '
                "dominance; pass band=(N, 2K+1) storage to build_dist_sap"
            )
        band_p, _ = pad_banded(
            jnp.asarray(band), jnp.zeros((n,), jnp.asarray(band).dtype), n_pad
        )
        with mesh:
            d_factor = float(dist_diag_dominance_factor(mesh, band_p))
        variant = resolve_variant("auto", d_factor)

    part_spec = P(axes)  # flattened over all axes

    def shard_band(band, b):
        """Host-side: pad + compute block-tridiag global arrays (numpy path,
        for examples/tests; the dry-run uses ShapeDtypeStructs instead)."""
        from .banded import band_to_block_tridiag

        band_p, b_p = pad_banded(jnp.asarray(band), jnp.asarray(b), n_pad)
        bt = band_to_block_tridiag(band_p, k, p_total)
        b_next = jnp.concatenate(
            [bt.b_cpl, jnp.zeros((1, k, k), bt.b_cpl.dtype)], axis=0
        )
        c_prev = jnp.concatenate(
            [jnp.zeros((1, k, k), bt.c_cpl.dtype), bt.c_cpl], axis=0
        )
        parts = {
            "d": bt.d.astype(precond_dtype),
            "e": bt.e.astype(precond_dtype),
            "f": bt.f.astype(precond_dtype),
            "b_next": b_next.astype(precond_dtype),
            "c_prev": c_prev.astype(precond_dtype),
        }
        return band_p, b_p, parts

    # ---- shard_mapped closures ---------------------------------------------
    # Every variant's factor returns an opaque per-device state pytree and
    # apply consumes it, so the shard_map plumbing is variant-independent.
    if variant == "C":
        def fac_local(d, e, f, b_next, c_prev):
            return _local_factor_c(d, e, f, b_next, c_prev, boost_eps, axes)

        def apply_local(state, b_next, c_prev, rb):
            return _local_apply_c(state, b_next, c_prev, rb, axes)
    elif variant == "E":
        def fac_local(d, e, f, b_next, c_prev):
            return _local_factor_e(
                d, e, f, b_next, c_prev, boost_eps, axes, p_total
            )

        def apply_local(state, b_next, c_prev, rb):
            return _local_apply_e(state, b_next, c_prev, rb, axes)
    else:
        def fac_local(d, e, f, b_next, c_prev):
            return (btf_ref(d, e, f, boost_eps),)

        def apply_local(state, b_next, c_prev, rb):
            return bts_ref(state[0], rb)

    fac_fn = shard_map(
        fac_local,
        mesh=mesh,
        in_specs=(part_spec,) * 5,
        out_specs=part_spec,
        check_vma=False,
    )

    apply_fn = shard_map(
        apply_local,
        mesh=mesh,
        in_specs=(part_spec,) * 4,
        out_specs=part_spec,
        check_vma=False,
    )

    mv_fn = shard_map(
        lambda band, x: _local_matvec(band, x, k, axes),
        mesh=mesh,
        in_specs=(part_spec, part_spec),
        out_specs=part_spec,
        check_vma=False,
    )

    return DistSaP(
        mesh=mesh,
        k=k,
        m=m,
        p_local=p_per_device,
        n_pad=n_pad,
        variant=variant,
        variant_requested=variant_requested,
        matvec=mv_fn,
        precond=apply_fn,
        factor=fac_fn,
        shard_band=shard_band,
        d_factor=d_factor,
    )


def solve_step_fn(dsap: DistSaP, tol: float = 1e-8, maxiter: int = 200):
    """Whole-solve function suitable for jit/lower on the production mesh.

    Inputs: band (N_pad, 2K+1) row-sharded, b (N_pad,) sharded, plus the
    block-tridiag partition arrays.  Returns a :class:`~repro.core.sap.
    SaPSolveResult` -- solution plus the convergence diagnostics
    (iterations / resnorm / converged, and the sharded d-estimate when
    the variant was resolved by "auto").
    """
    k, m = dsap.k, dsap.m
    d_factor = dsap.d_factor

    def step(band, b, d, e, f, b_next, c_prev):
        state = dsap.factor(d, e, f, b_next, c_prev)
        p_total = d.shape[0]

        def precond(r):
            rb = r.reshape(p_total, m, k, 1).astype(d.dtype)
            z = dsap.precond(state, b_next, c_prev, rb)
            return z.reshape(r.shape).astype(r.dtype)

        def matvec(x):
            return dsap.matvec(band, x)

        res = bicgstab2(matvec, b, precond=precond, tol=tol, maxiter=maxiter)
        return SaPSolveResult(
            x=res.x,
            iterations=res.iterations,
            resnorm=res.resnorm,
            converged=res.converged,
            true_resnorm=res.true_resnorm,
            d_factor=None if d_factor is None else jnp.asarray(d_factor),
        )

    return step
