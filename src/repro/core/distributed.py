"""Multi-device SaP: partition-per-device solver via shard_map.

The paper's P-way work splitting maps 1:1 onto the TPU mesh: every device
owns ``p_per_device`` partitions; factorization and the two block solves
of the preconditioner are embarrassingly parallel, and the *only*
communication in the whole preconditioner is nearest-neighbor:

  setup:  one ppermute of the left-spike top blocks  W^(t)   (K x K each)
  apply:  one ppermute of g^(t) (down) + one of xt^(b) (up)  (K x R each)

i.e. O(K^2) / O(K R) bytes per device per apply, independent of N -- the
TPU analogue of the paper's observation that the reduced system is tiny.
The banded matvec for the outer Krylov iteration needs a K-row halo
exchange (two ppermutes).  Everything else (dots, norms in BiCGStab) is
left to pjit/GSPMD at the top level.

Partitions are flattened over *all* mesh axes (tuple-axis collectives), so
the same code runs on the (data, model) single-pod mesh and the
(pod, data, model) multi-pod mesh -- partition boundaries crossing the pod
axis prove the pod-level sharding in the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from .banded import pad_banded
from .block_lu import DEFAULT_BOOST, btf_ref, btf_ul_ref, bts_ref, gj_inverse
from .krylov import bicgstab2


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def n_devices(mesh) -> int:
    return int(mesh.devices.size)


# ---------------------------------------------------------------------------
# Neighbor shifts over the flattened mesh axes (non-cyclic: edges get zeros)
# ---------------------------------------------------------------------------


def _shift_from_next(x, axes):
    """Each device receives the value owned by device (idx+1); last gets 0."""
    n = axis_size(axes)
    perm = [(i + 1, i) for i in range(n - 1)]
    return jax.lax.ppermute(x, axes, perm)


def _shift_from_prev(x, axes):
    """Each device receives the value owned by device (idx-1); first gets 0."""
    n = axis_size(axes)
    perm = [(i, i + 1) for i in range(n - 1)]
    return jax.lax.ppermute(x, axes, perm)


# ---------------------------------------------------------------------------
# Distributed preconditioner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistSaP:
    """Compiled distributed solver handle."""

    mesh: object
    k: int
    m: int
    p_local: int
    n_pad: int
    variant: str
    matvec: callable
    precond: callable
    factor: callable
    shard_band: callable


def _local_factor(d, e, f, b_next, c_prev, boost_eps, variant, axes):
    """Runs per device.  d/e/f: (p_loc, M, K, K); couplings per partition."""
    lu = btf_ref(d, e, f, boost_eps)
    if variant == "D":
        return lu, None, None, None
    # right-spike bottoms (for interface owned by this partition)
    v_bot = lu.sinv[:, -1] @ b_next  # (p_loc, K, K)
    # left-spike tops of *this* partition (for the interface owned by prev)
    ul = btf_ul_ref(d, e, f, boost_eps)
    w_top = (ul.sinv[:, -1] @ c_prev[..., ::-1, :])[..., ::-1, :]
    # align W^(t) of partition i+1 at interface index i
    w_next = jnp.concatenate(
        [w_top[1:], _shift_from_next(w_top[:1], axes)], axis=0
    )
    eye = jnp.eye(d.shape[-1], dtype=d.dtype)
    rbar = eye - w_next @ v_bot
    rbar_inv = jax.vmap(lambda a: gj_inverse(a, boost_eps))(rbar)
    return lu, v_bot, w_next, rbar_inv


def _local_apply(lu, v_bot, w_next, rbar_inv, b_next, c_prev, rb, variant, axes):
    """Per-device preconditioner apply.  rb: (p_loc, M, K, R)."""
    g = bts_ref(lu, rb)
    if variant == "D":
        return g
    g_top, g_bot = g[:, 0], g[:, -1]  # (p_loc, K, R)
    # g^(t) of partition i+1 aligned at interface i
    g_top_next = jnp.concatenate(
        [g_top[1:], _shift_from_next(g_top[:1], axes)], axis=0
    )
    rhs = g_top_next - w_next @ g_bot
    xt_top = rbar_inv @ rhs  # x~ for top of partition i+1
    xt_bot = g_bot - v_bot @ xt_top  # x~ for bottom of partition i
    # partition j needs: bottom corr B_j xt_top[j] (local); top corr
    # C_j xt_bot[j-1] (shift up)
    xt_bot_prev = jnp.concatenate(
        [_shift_from_prev(xt_bot[-1:], axes), xt_bot[:-1]], axis=0
    )
    rb2 = rb.at[:, -1].add(-(b_next @ xt_top))
    rb2 = rb2.at[:, 0].add(-(c_prev @ xt_bot_prev))
    return bts_ref(lu, rb2)


def _local_matvec(band_loc, x_loc, k, axes):
    """Banded matvec with K-row halo exchange.  band_loc: (N_loc, 2K+1)."""
    lo = _shift_from_prev(x_loc[-k:], axes)  # prev device's last K entries
    hi = _shift_from_next(x_loc[:k], axes)  # next device's first K entries
    x_ext = jnp.concatenate([lo, x_loc, hi], axis=0)
    n_loc = x_loc.shape[0]
    cols = [band_loc[:, j] * jax.lax.dynamic_slice(x_ext, (j,), (n_loc,))
            for j in range(2 * k + 1)]
    return sum(cols)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


def build_dist_sap(
    mesh,
    n: int,
    k: int,
    variant: str = "C",
    p_per_device: int = 1,
    boost_eps: float = DEFAULT_BOOST,
    precond_dtype=jnp.float32,
):
    """Construct the shard_mapped matvec/precond/factor closures.

    Returns a :class:`DistSaP`; all functions operate on globally-sharded
    arrays and can be jit/lowered on the production mesh.
    """
    axes = mesh_axes(mesh)
    ndev = n_devices(mesh)
    p_total = ndev * p_per_device
    ni = -(-n // p_total)  # ceil rows per partition
    m = max(2, -(-ni // k))  # blocks per partition (>= 2 so top != bottom)
    n_pad = p_total * m * k

    part_spec = P(axes)  # flattened over all axes

    def shard_band(band, b):
        """Host-side: pad + compute block-tridiag global arrays (numpy path,
        for examples/tests; the dry-run uses ShapeDtypeStructs instead)."""
        from .banded import band_to_block_tridiag

        band_p, b_p = pad_banded(jnp.asarray(band), jnp.asarray(b), n_pad)
        bt = band_to_block_tridiag(band_p, k, p_total)
        b_next = jnp.concatenate(
            [bt.b_cpl, jnp.zeros((1, k, k), bt.b_cpl.dtype)], axis=0
        )
        c_prev = jnp.concatenate(
            [jnp.zeros((1, k, k), bt.c_cpl.dtype), bt.c_cpl], axis=0
        )
        parts = {
            "d": bt.d.astype(precond_dtype),
            "e": bt.e.astype(precond_dtype),
            "f": bt.f.astype(precond_dtype),
            "b_next": b_next.astype(precond_dtype),
            "c_prev": c_prev.astype(precond_dtype),
        }
        return band_p, b_p, parts

    # ---- shard_mapped closures ---------------------------------------------
    if variant == "C":
        def fac_local(d, e, f, b_next, c_prev):
            return _local_factor(d, e, f, b_next, c_prev, boost_eps, "C", axes)

        def apply_local(lu, v_bot, w_next, rbar_inv, b_next, c_prev, rb):
            return _local_apply(
                lu, v_bot, w_next, rbar_inv, b_next, c_prev, rb, "C", axes
            )
    else:
        def fac_local(d, e, f, b_next, c_prev):
            lu = btf_ref(d, e, f, boost_eps)
            zero = jnp.zeros_like(d[:, 0])
            return lu, zero, zero, zero

        def apply_local(lu, v_bot, w_next, rbar_inv, b_next, c_prev, rb):
            return bts_ref(lu, rb)

    fac_fn = shard_map(
        fac_local,
        mesh=mesh,
        in_specs=(part_spec,) * 5,
        out_specs=(part_spec, part_spec, part_spec, part_spec),
        check_vma=False,
    )

    apply_fn = shard_map(
        apply_local,
        mesh=mesh,
        in_specs=(part_spec,) * 7,
        out_specs=part_spec,
        check_vma=False,
    )

    mv_fn = shard_map(
        lambda band, x: _local_matvec(band, x, k, axes),
        mesh=mesh,
        in_specs=(part_spec, part_spec),
        out_specs=part_spec,
        check_vma=False,
    )

    return DistSaP(
        mesh=mesh,
        k=k,
        m=m,
        p_local=p_per_device,
        n_pad=n_pad,
        variant=variant,
        matvec=mv_fn,
        precond=apply_fn,
        factor=fac_fn,
        shard_band=shard_band,
    )


def solve_step_fn(dsap: DistSaP, tol: float = 1e-8, maxiter: int = 200):
    """Whole-solve function suitable for jit/lower on the production mesh.

    Inputs: band (N_pad, 2K+1) row-sharded, b (N_pad,) sharded, plus the
    block-tridiag partition arrays.  Output: x, iterations, resnorm.
    """
    k, m = dsap.k, dsap.m
    variant = dsap.variant

    def step(band, b, d, e, f, b_next, c_prev):
        lu, v_bot, w_next, rbar_inv = dsap.factor(d, e, f, b_next, c_prev)
        p_total = d.shape[0]

        def precond(r):
            rb = r.reshape(p_total, m, k, 1).astype(d.dtype)
            z = dsap.precond(lu, v_bot, w_next, rbar_inv, b_next, c_prev, rb)
            return z.reshape(r.shape).astype(r.dtype)

        def matvec(x):
            return dsap.matvec(band, x)

        res = bicgstab2(matvec, b, precond=precond, tol=tol, maxiter=maxiter)
        return res.x, res.iterations, res.resnorm

    return step
