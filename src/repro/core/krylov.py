"""Krylov-subspace solvers: BiCGStab(2) and CG, left-preconditioned.

Paper Sec. 2.1.1: the SaP preconditioner (coupled or decoupled) is wrapped
in BiCGStab(l) [Sleijpen & Fokkema 1993] with l = 2, or CG when the matrix
is symmetric positive definite.  Following the paper's convention, BiCGStab
iterations are counted in *quarters* (the algorithm has intermediate exit
points); we track them the same way so benchmark tables line up with
Tables 4.1 / 4.2.

Mixed precision (paper Sec. 3.1): the preconditioner apply runs in its own
(lower) storage dtype; the outer iteration runs in the dtype of ``b``.

``matvec`` / ``precond`` may be plain callables or anything exposing a
``.matvec`` method (a :class:`repro.core.operators.LinearOperator`).
Multi-RHS systems use :func:`bicgstab2_many` / :func:`cg_many`, which vmap
the solver over a trailing batch axis of ``b`` -- each column converges
independently (converged columns freeze while stragglers iterate).

Everything is expressed with ``jax.lax.while_loop`` so it stays on-device
and can be jitted / sharded.  The underscore ``_*_impl`` variants are the
unjitted bodies, for embedding inside an enclosing jit (e.g. the
``SaPFactorization.solve`` path) without nested-jit cache churn.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

from .operators import LinearOperator, as_matvec

MatVec = Union[Callable[[jax.Array], jax.Array], LinearOperator]


class KrylovResult(NamedTuple):
    """Solver exit state.

    ``converged``/``resnorm`` report the *preconditioned* residual the
    iteration actually controls (``M^-1 (b - A x)`` under left
    preconditioning): that is what ``tol`` bounds, and a strong but
    *inexact* preconditioner can meet it while ``b - A x`` is still
    large.  ``true_resnorm`` is the unpreconditioned check
    ``||b - A x|| / ||b||``, recomputed from scratch at exit (one extra
    matvec) -- the quantity callers should trust.
    """

    x: jax.Array
    iterations: jax.Array  # fractional iterations (quarters for BiCGStab)
    resnorm: jax.Array  # preconditioned residual norm at exit
    converged: jax.Array
    true_resnorm: jax.Array | None = None  # ||b - A x|| / ||b||
    # With record_history=True: (maxiter,) preconditioned relative residual
    # after each outer sweep, NaN-padded past the exit sweep.  The number of
    # non-NaN entries is ceil(iterations) (BiCGStab quarter-exits record the
    # sweep they exit from); entry i is the residual the convergence test saw
    # at the end of sweep i.  None when history was not requested.
    history: jax.Array | None = None


def _true_resnorm(matvec, b, x) -> jax.Array:
    """Unpreconditioned relative residual, recomputed (not the recurrence)."""
    bn = jnp.linalg.norm(b)
    bn = jnp.where(bn > 0, bn, 1.0)
    return jnp.linalg.norm(b - matvec(x).astype(b.dtype)) / bn


def _identity(x):
    return x


def _dot(a, b):
    return jnp.sum(a * b)


# ---------------------------------------------------------------------------
# BiCGStab(2)  (Sleijpen & Fokkema), left preconditioning: solve M^-1 A x = M^-1 b
# ---------------------------------------------------------------------------


def _bicgstab2_impl(
    matvec: MatVec,
    b: jax.Array,
    precond: MatVec = _identity,
    x0: jax.Array | None = None,
    tol: float = 1e-10,
    maxiter: int = 500,
    record_history: bool = False,
) -> KrylovResult:
    """BiCGStab(2) with left preconditioning (unjitted body).

    One outer "iteration" = two matvec+precond in the BiCG part plus two in
    the MR part, counted as 4 quarter-exits to mirror the paper's tables.

    ``record_history`` is a static flag: when True a fixed-size ``(maxiter,)``
    NaN-initialized residual array rides through the while_loop state and is
    returned on ``KrylovResult.history``; when False the loop state is
    byte-identical to before the flag existed (no recompilation of cached
    history-free executables).
    """
    dtype = b.dtype
    op = lambda v: precond(matvec(v)).astype(dtype)

    x = jnp.zeros_like(b) if x0 is None else x0
    r0 = precond(b - matvec(x)).astype(dtype)
    bnorm = jnp.linalg.norm(precond(b).astype(dtype))
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)
    rtilde = r0
    eps = jnp.asarray(1e-300 if dtype == jnp.float64 else 1e-30, dtype)

    def cond(state):
        (x, r, u, rho, omega, alpha, it, done) = state[:8]
        return (~done) & (it < maxiter)

    def _select(c, a, b):
        return jax.tree.map(lambda p, q: jnp.where(c, p, q), a, b)

    def body(state):
        """One BiCGStab(2) sweep (Sleijpen & Fokkema Alg. 3.1, l = 2).

        The algorithm has intermediate exit points (the paper counts them
        as quarter iterations, Sec. 4.1.1).  If an early exit triggers we
        keep the snapshot at that point -- continuing the sweep with a
        (near-)zero residual would divide by degenerate inner products.
        """
        (x, r0, u0, rho0, omega, alpha, it, done) = state[:8]
        # `it` at sweep entry is always whole (quarter-exits end the loop),
        # so it doubles as the 0-based history index for this sweep.
        sweep_idx = it.astype(jnp.int32)
        rho0 = -omega * rho0

        # ---- BiCG part, j = 0 -------------------------------------------
        rho1 = _dot(r0, rtilde)
        beta = jnp.where(jnp.abs(rho0) > eps, alpha * rho1 / rho0, 0.0)
        rho0 = rho1
        u0 = r0 - beta * u0
        u1 = op(u0)
        gamma = _dot(u1, rtilde)
        alpha = jnp.where(jnp.abs(gamma) > eps, rho0 / gamma, 0.0)
        r0 = r0 - alpha * u1
        r1 = op(r0)
        x = x + alpha * u0
        q1 = jnp.linalg.norm(r0) <= tol * bnorm  # quarter-exit 1
        snap1 = (x, r0, u0, rho0, omega, alpha, it + 0.25, q1)

        # ---- BiCG part, j = 1 -------------------------------------------
        rho1 = _dot(r1, rtilde)
        beta = jnp.where(jnp.abs(rho0) > eps, alpha * rho1 / rho0, 0.0)
        rho0 = rho1
        u0 = r0 - beta * u0
        u1 = r1 - beta * u1
        u2 = op(u1)
        gamma = _dot(u2, rtilde)
        alpha = jnp.where(jnp.abs(gamma) > eps, rho0 / gamma, 0.0)
        r0 = r0 - alpha * u1
        r1 = r1 - alpha * u2
        r2 = op(r1)
        x = x + alpha * u0
        q2 = jnp.linalg.norm(r0) <= tol * bnorm  # quarter-exit 2
        snap2 = (x, r0, u0, rho0, omega, alpha, it + 0.5, q2)

        # ---- MR part (modified Gram-Schmidt on r1, r2) -------------------
        # Degeneracy guard: when the preconditioner is (near-)exact,
        # r2 - tau12 r1 is rounding noise; using it poisons x while the
        # recurrence residual stays small.  Detect via the relative norm of
        # the orthogonalized direction and fall back to the l=1 step.
        sigma1 = jnp.maximum(_dot(r1, r1), eps)
        gp1 = _dot(r0, r1) / sigma1
        tau12 = _dot(r2, r1) / sigma1
        r2o = r2 - tau12 * r1
        sigma2 = _dot(r2o, r2o)
        ratio_eps = jnp.asarray(
            (50 * jnp.finfo(dtype).eps) ** 2, dtype
        )
        degenerate = sigma2 <= ratio_eps * sigma1
        gp2 = jnp.where(
            degenerate, 0.0, _dot(r0, r2o) / jnp.maximum(sigma2, eps)
        )
        g2 = gp2
        omega_new = jnp.where(degenerate, gp1, g2)
        g1 = gp1 - tau12 * g2
        gpp1 = g2  # gamma''_1 = gamma_2 (l = 2)

        x = x + g1 * r0 + gpp1 * r1
        r0 = r0 - gp1 * r1 - gp2 * r2o
        u0 = u0 - g1 * u1 - g2 * u2

        q4 = jnp.linalg.norm(r0) <= tol * bnorm
        full = (x, r0, u0, rho0, omega_new, alpha, it + 1.0, q4)
        new = _select(q1, snap1, _select(q2, snap2, full))
        if record_history:
            hist = state[8].at[sweep_idx].set(jnp.linalg.norm(new[1]) / bnorm)
            return new + (hist,)
        return new

    u = jnp.zeros_like(b)
    state = (
        x,
        r0,
        u,
        jnp.asarray(1.0, dtype),  # rho0
        jnp.asarray(1.0, dtype),  # omega
        jnp.asarray(0.0, dtype),  # alpha
        jnp.asarray(0.0, dtype),  # iterations
        jnp.linalg.norm(r0) <= tol * bnorm,
    )
    if record_history:
        state = state + (jnp.full((maxiter,), jnp.nan, dtype),)
    out = jax.lax.while_loop(cond, body, state)
    (x, r, _, _, _, _, it, done) = out[:8]
    rnorm = jnp.linalg.norm(r)
    return KrylovResult(
        x=x,
        iterations=it,
        resnorm=rnorm / bnorm,
        converged=done,
        true_resnorm=_true_resnorm(matvec, b, x),
        history=out[8] if record_history else None,
    )


_bicgstab2_jit = jax.jit(
    _bicgstab2_impl,
    static_argnames=("matvec", "precond", "maxiter", "record_history"),
)


def bicgstab2(
    matvec: MatVec,
    b: jax.Array,
    precond: MatVec = _identity,
    x0: jax.Array | None = None,
    tol: float = 1e-10,
    maxiter: int = 500,
    record_history: bool = False,
) -> KrylovResult:
    """Jitted BiCGStab(2); accepts callables or LinearOperators."""
    return _bicgstab2_jit(
        as_matvec(matvec), b, as_matvec(precond), x0, tol, maxiter, record_history
    )


# ---------------------------------------------------------------------------
# Preconditioned CG (paper: used when A is SPD)
# ---------------------------------------------------------------------------


def _cg_impl(
    matvec: MatVec,
    b: jax.Array,
    precond: MatVec = _identity,
    x0: jax.Array | None = None,
    tol: float = 1e-10,
    maxiter: int = 1000,
    record_history: bool = False,
) -> KrylovResult:
    dtype = b.dtype
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    z = precond(r).astype(dtype)
    p = z
    rz = _dot(r, z)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)

    def cond(state):
        (x, r, z, p, rz, it, done) = state[:7]
        return (~done) & (it < maxiter)

    def body(state):
        (x, r, z, p, rz, it, done) = state[:7]
        ap = matvec(p)
        denom = _dot(p, ap)
        alpha = jnp.where(jnp.abs(denom) > 0, rz / denom, 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        z = precond(r).astype(dtype)
        rz_new = _dot(r, z)
        beta = jnp.where(jnp.abs(rz) > 0, rz_new / rz, 0.0)
        p = z + beta * p
        rnorm = jnp.linalg.norm(r)
        done = rnorm <= tol * bnorm
        new = (x, r, z, p, rz_new, it + 1.0, done)
        if record_history:
            hist = state[7].at[it.astype(jnp.int32)].set(rnorm / bnorm)
            return new + (hist,)
        return new

    state = (
        x,
        r,
        z,
        p,
        rz,
        jnp.asarray(0.0, dtype),
        jnp.linalg.norm(r) <= tol * bnorm,
    )
    if record_history:
        state = state + (jnp.full((maxiter,), jnp.nan, dtype),)
    out = jax.lax.while_loop(cond, body, state)
    (x, r, _, _, _, it, done) = out[:7]
    return KrylovResult(
        x=x,
        iterations=it,
        resnorm=jnp.linalg.norm(r) / bnorm,
        converged=done,
        true_resnorm=_true_resnorm(matvec, b, x),
        history=out[7] if record_history else None,
    )


_cg_jit = jax.jit(
    _cg_impl, static_argnames=("matvec", "precond", "maxiter", "record_history")
)


def cg(
    matvec: MatVec,
    b: jax.Array,
    precond: MatVec = _identity,
    x0: jax.Array | None = None,
    tol: float = 1e-10,
    maxiter: int = 1000,
    record_history: bool = False,
) -> KrylovResult:
    """Jitted preconditioned CG; accepts callables or LinearOperators."""
    return _cg_jit(
        as_matvec(matvec), b, as_matvec(precond), x0, tol, maxiter, record_history
    )


# ---------------------------------------------------------------------------
# Iterative refinement (mixed precision: low-dtype factor, high-dtype loop)
# ---------------------------------------------------------------------------


def _refine_impl(
    matvec: MatVec,
    b: jax.Array,
    precond: MatVec = _identity,
    x0: jax.Array | None = None,
    tol: float = 1e-10,
    maxiter: int = 500,
    record_history: bool = False,
) -> KrylovResult:
    """Preconditioned iterative refinement (Richardson iteration).

    The mixed-precision workhorse (paper Sec. 3.1 economics): ``precond``
    is a *low-precision* approximate inverse (e.g. an f32 SaP
    factorization) and the outer loop runs in the dtype of ``b`` (e.g.
    f64).  Each sweep computes the residual ``r = b - A x`` in the outer
    dtype, applies the preconditioner to get a correction, and adds it:

        x_{k+1} = x_k + M^-1 (b - A x_k)

    Convergence is linear with rate ``||I - M^-1 A||``, but -- unlike the
    Krylov loops above -- the controlled residual IS the true residual:
    ``resnorm`` and ``true_resnorm`` agree by construction, and the final
    accuracy is set by the outer dtype, not the factorization dtype.
    Requires a convergent splitting (a good enough preconditioner); for
    marginal preconditioners use BiCGStab(2) instead.
    """
    dtype = b.dtype
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x).astype(dtype)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)

    def cond(state):
        (x, r, it, done) = state[:4]
        return (~done) & (it < maxiter)

    def body(state):
        (x, r, it, done) = state[:4]
        # correction in the (low) preconditioner dtype, applied in `dtype`
        x = x + precond(r).astype(dtype)
        r = b - matvec(x).astype(dtype)
        rnorm = jnp.linalg.norm(r)
        done = rnorm <= tol * bnorm
        new = (x, r, it + 1.0, done)
        if record_history:
            hist = state[4].at[it.astype(jnp.int32)].set(rnorm / bnorm)
            return new + (hist,)
        return new

    state = (
        x,
        r,
        jnp.asarray(0.0, dtype),
        jnp.linalg.norm(r) <= tol * bnorm,
    )
    if record_history:
        state = state + (jnp.full((maxiter,), jnp.nan, dtype),)
    out = jax.lax.while_loop(cond, body, state)
    (x, r, it, done) = out[:4]
    rnorm = jnp.linalg.norm(r)
    return KrylovResult(
        x=x,
        iterations=it,
        resnorm=rnorm / bnorm,
        converged=done,
        # the refinement residual is already the true residual; recompute
        # anyway so the contract ("recomputed at exit") matches the others
        true_resnorm=_true_resnorm(matvec, b, x),
        history=out[4] if record_history else None,
    )


_refine_jit = jax.jit(
    _refine_impl,
    static_argnames=("matvec", "precond", "maxiter", "record_history"),
)


def refine(
    matvec: MatVec,
    b: jax.Array,
    precond: MatVec = _identity,
    x0: jax.Array | None = None,
    tol: float = 1e-10,
    maxiter: int = 500,
    record_history: bool = False,
) -> KrylovResult:
    """Jitted iterative refinement; accepts callables or LinearOperators."""
    return _refine_jit(
        as_matvec(matvec), b, as_matvec(precond), x0, tol, maxiter, record_history
    )


# ---------------------------------------------------------------------------
# Multi-RHS: vmap a single-RHS solver over a trailing batch axis of b
# ---------------------------------------------------------------------------


def _vmap_rhs(impl, default_maxiter):
    def many(
        matvec: MatVec,
        b: jax.Array,
        precond: MatVec = _identity,
        x0: jax.Array | None = None,
        tol: float = 1e-10,
        maxiter: int = default_maxiter,
        record_history: bool = False,
    ) -> KrylovResult:
        """Solve A X = B for B of shape (N, R): one Krylov run per column.

        Returns a KrylovResult with x (N, R) and per-column iterations /
        resnorm / converged of shape (R,); with ``record_history=True``,
        ``history`` is (R, maxiter) -- row r is column r's residual track.
        Unjitted: wrap in jax.jit (or call via SaPFactorization.solve_many)
        for a cached executable.
        """
        out_axes = KrylovResult(
            x=1,
            iterations=0,
            resnorm=0,
            converged=0,
            true_resnorm=0,
            history=0 if record_history else None,
        )
        mv, pc = as_matvec(matvec), as_matvec(precond)
        if x0 is None:
            fn = lambda bi: impl(mv, bi, pc, None, tol, maxiter, record_history)
            return jax.vmap(fn, in_axes=1, out_axes=out_axes)(b)
        fn = lambda bi, xi: impl(mv, bi, pc, xi, tol, maxiter, record_history)
        return jax.vmap(fn, in_axes=(1, 1), out_axes=out_axes)(b, x0)

    return many


bicgstab2_many = _vmap_rhs(_bicgstab2_impl, 500)
cg_many = _vmap_rhs(_cg_impl, 1000)
refine_many = _vmap_rhs(_refine_impl, 500)
