"""Linear operators: the one matvec interface the solver stack speaks.

``plan`` / ``factor`` / ``solve`` (see :mod:`repro.core.sap`) exchange
matrices exclusively through these operator objects, so the Krylov loop,
the preconditioner assembly, and the benchmarks all see the same surface
regardless of storage format:

* :class:`BandedOperator` -- the paper's "tall and thin" (N, 2K+1) band
  storage (Sec. 3.1); matvec is the shifted-diagonal product.
* :class:`CsrOperator`   -- general sparse matrices in expanded-COO form
  on device; matvec is a ``segment_sum`` gather/scatter.

Both are registered JAX pytrees: they can live inside jitted functions,
``SaPFactorization`` handles, and vmapped solves.  ``matvec`` accepts a
single vector ``(N,)`` or a trailing-batch matrix ``(N, R)`` of
right-hand-side columns and preserves that shape.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .banded import band_matvec


class LinearOperator:
    """Marker base class: anything with ``.n``, ``.dtype`` and ``.matvec``."""

    n: int

    def matvec(self, x: jax.Array) -> jax.Array:  # pragma: no cover
        raise NotImplementedError

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.matvec(x)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("band",),
    meta_fields=("n", "k"),
)
@dataclasses.dataclass(eq=False)
class BandedOperator(LinearOperator):
    """Dense banded matrix in (N, 2K+1) band storage."""

    band: jax.Array
    n: int
    k: int

    @classmethod
    def from_band(cls, band) -> "BandedOperator":
        band = jnp.asarray(band)
        n, w = band.shape
        return cls(band=band, n=n, k=(w - 1) // 2)

    @property
    def dtype(self):
        return self.band.dtype

    def matvec(self, x: jax.Array) -> jax.Array:
        return band_matvec(self.band, x)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("data", "rows", "cols"),
    meta_fields=("n",),
)
@dataclasses.dataclass(eq=False)
class CsrOperator(LinearOperator):
    """Sparse matrix as device-resident expanded COO (rows, cols, data)."""

    data: jax.Array  # (nnz,)
    rows: jax.Array  # (nnz,) int32 row id per entry
    cols: jax.Array  # (nnz,) int32 column index per entry
    n: int

    @classmethod
    def from_csr(cls, csr, dtype=None) -> "CsrOperator":
        """Build from a host-side :class:`repro.core.sparse.CSR`.

        ``dtype`` defaults to the canonical float dtype (float64 only when
        x64 is enabled) -- NOT a hard-coded float32, so f64 sessions keep
        full precision in the matvec.
        """
        if dtype is None:
            dtype = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
        return cls(
            data=jnp.asarray(csr.data, dtype=dtype),
            rows=jnp.asarray(csr.row_ids(), dtype=jnp.int32),
            cols=jnp.asarray(csr.indices, dtype=jnp.int32),
            n=csr.n,
        )

    @property
    def dtype(self):
        return self.data.dtype

    def matvec(self, x: jax.Array) -> jax.Array:
        data = self.data.astype(x.dtype)
        prod = data[:, None] * x[self.cols] if x.ndim == 2 else data * x[self.cols]
        return jax.ops.segment_sum(prod, self.rows, num_segments=self.n)

    def to_csr(self):
        """Reconstruct a host-side CSR (sorts and merges the COO entries,
        so operators built from unsorted triplets round-trip correctly)."""
        from .sparse import csr_from_coo

        return csr_from_coo(
            self.n,
            np.asarray(self.rows),
            np.asarray(self.cols),
            np.asarray(self.data, dtype=np.float64),
        )


def require_square_dense(a) -> None:
    """Reject raw arrays that are not dense square matrices.

    Band-storage (N, 2K+1) arrays are ambiguous with dense matrices, so
    raw arrays are only accepted when square; band storage must be wrapped
    explicitly.
    """
    if np.ndim(a) != 2 or a.shape[0] != a.shape[1]:
        raise TypeError(
            f"raw arrays must be dense square matrices, got shape "
            f"{np.shape(a)}; use BandedOperator.from_band / plan_banded "
            f"for (N, 2K+1) band storage"
        )


def as_matvec(op):
    """Normalize an operator-or-callable into a matvec callable."""
    if isinstance(op, LinearOperator):
        return op.matvec
    mv = getattr(op, "matvec", None)
    return mv if mv is not None else op


def as_operator(a) -> LinearOperator:
    """Coerce ``a`` into a :class:`LinearOperator`.

    Accepts an operator (returned as-is), a host CSR / scipy sparse matrix,
    or a dense (N, N) array.  Band-storage arrays are ambiguous with dense
    matrices -- wrap those explicitly with :meth:`BandedOperator.from_band`.
    """
    if isinstance(a, LinearOperator):
        return a
    from . import reorder as reorder_mod  # local import: no cycles

    if isinstance(a, jax.Array):
        a = np.asarray(a)
    if isinstance(a, np.ndarray):
        require_square_dense(a)
    return CsrOperator.from_csr(reorder_mod.to_csr(a))
