"""Matrix reordering algorithms (paper Sec. 2.2.1, 3.2, 3.3).

* ``diagonal_boosting`` (DB): row permutation maximizing the product of
  absolute diagonal values, reduced to min-weight bipartite perfect
  matching with weights c_ij = log(max_j |a_ij|) - log|a_ij| (Eq. 2.12).
  Implemented as the four stages of the paper:
    DB-S1 form weighted bipartite graph
    DB-S2 initial partial match from potentials (length-1 augmenting paths)
    DB-S3 perfect match via Dijkstra shortest augmenting paths
    DB-S4 extract permutation (+ optional I-matrix scaling factors)

* ``cuthill_mckee`` (CM): bandwidth-reducing BFS ordering with the paper's
  heuristics (Sec. 3.3): multiple starting nodes, neighbor pre-sorting by
  ascending degree, termination when tree height stops growing / max level
  width stops shrinking, <= 3 CM iterations.

* ``third_stage``: independent per-partition CM (Sec. 4.3.2), returning
  per-partition K_i.

* ``drop_off``: removes smallest off-band elements subject to a fraction
  of the total absolute mass, to shrink the half-bandwidth (T_Drop).

These run on the host (numpy), exactly as SaP::GPU runs its reordering
stages partially on the CPU (hybrid strategy, Sec. 3.2-3.3).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Tuple

import numpy as np

from ..obs.trace import span
from .sparse import CSR, csr_from_coo, csr_from_dense

INF = np.inf


@dataclasses.dataclass
class ReorderPlan:
    """Host-side result of the DB/CM/drop-off analysis (paper Fig. 3.1).

    The permutations are stored once here and applied/undone inside the
    device-side solve; re-running the analysis per right-hand side is the
    exact waste the plan/factor/solve lifecycle removes.

    csr     : fully reordered matrix (the Krylov matvec ordering)
    b_perm  : composed RHS permutation, ``b_reordered = b[b_perm]``
    x_perm  : inverse unknown permutation, ``x = x_reordered[x_perm]``
    k       : preconditioner half bandwidth (after drop-off, >= 1)
    band_pc : (N, 2K+1) band assembly of the preconditioner matrix
    info    : stage diagnostics (k_after_reorder, k_after_drop, ...)
    """

    csr: CSR
    b_perm: np.ndarray
    x_perm: np.ndarray
    k: int
    band_pc: np.ndarray
    info: dict


def analyze(
    a,
    use_db: bool = True,
    use_cm: bool = True,
    drop_tol: float = 0.0,
) -> ReorderPlan:
    """Run the sparse front end once: DB -> CM -> drop-off -> band assembly.

    Pipeline stages T_DB .. T_Asmbl of paper Fig. 3.1.  Drop-off only
    affects the preconditioner band; ``csr`` keeps every element so the
    Krylov matvec uses the exact (reordered) matrix.
    """
    csr = to_csr(a)
    n = csr.n
    info: dict = {}

    with span("reorder", n=n, nnz=int(csr.data.size), drop_tol=drop_tol) as rsp:
        if use_db:
            with span("reorder.db"):
                row_perm = diagonal_boosting(csr)
                csr = permute_rows(csr, row_perm)
            info["db"] = True
        else:
            row_perm = np.arange(n)
            info["db"] = False

        if use_cm:
            with span("reorder.cm"):
                sym_perm = cuthill_mckee(symmetrize(csr))
                csr = permute_symmetric(csr, sym_perm)
            info["cm"] = True
        else:
            sym_perm = np.arange(n)
            info["cm"] = False

        k_full = half_bandwidth(csr)
        info["k_after_reorder"] = k_full

        csr_pc = csr
        k = k_full
        if drop_tol > 0.0:
            with span("reorder.drop"):
                csr_pc, k = drop_off(csr, drop_tol)
            info["k_after_drop"] = k
        k = max(k, 1)
        rsp.annotate(k=k)

        with span("reorder.assemble"):
            band_pc = csr_to_band(csr_pc, k)

    return ReorderPlan(
        csr=csr,
        b_perm=row_perm[sym_perm],
        x_perm=np.argsort(sym_perm),
        k=k,
        band_pc=band_pc,
        info=info,
    )


def to_csr(a) -> CSR:
    if isinstance(a, CSR):
        return a
    if hasattr(a, "tocsr"):  # scipy
        m = a.tocsr()
        return CSR(
            indptr=np.asarray(m.indptr, dtype=np.int64),
            indices=np.asarray(m.indices, dtype=np.int64),
            data=np.asarray(m.data, dtype=np.float64),
            n=m.shape[0],
        )
    return csr_from_dense(np.asarray(a))


# ---------------------------------------------------------------------------
# DB: diagonal boosting via min-weight bipartite perfect matching
# ---------------------------------------------------------------------------


def diagonal_boosting(
    csr: CSR, return_scaling: bool = False
) -> np.ndarray | Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Row permutation sigma maximizing prod |a_{i, sigma_i}|.

    Returns ``row_perm`` such that ``A[row_perm]`` has the boosted diagonal;
    i.e. row_perm[new_row] = old_row, with column j matched to old row
    row_perm[j].
    """
    n = csr.n
    indptr, indices, data = csr.indptr, csr.indices, csr.data

    # ---- DB-S1: weights c_ij = log a_i - log |a_ij| ------------------------
    absdata = np.abs(data)
    rowmax = np.zeros(n)
    rows = csr.row_ids()
    np.maximum.at(rowmax, rows, absdata)
    rowmax = np.maximum(rowmax, 1e-300)
    with np.errstate(divide="ignore"):
        w = np.log(rowmax[rows]) - np.log(np.maximum(absdata, 1e-300))
    w = np.where(absdata == 0.0, INF, w)

    # ---- DB-S2: initial potentials + greedy partial match ------------------
    u = np.full(n, INF)  # row potential: min_j c_ij
    np.minimum.at(u, rows, w)
    u = np.where(np.isfinite(u), u, 0.0)
    v = np.full(n, INF)  # col potential: min_i (c_ij - u_i)
    np.minimum.at(v, indices, w - u[rows])
    v = np.where(np.isfinite(v), v, 0.0)

    row_of_col = np.full(n, -1, dtype=np.int64)  # matching: column -> row
    col_of_row = np.full(n, -1, dtype=np.int64)
    # greedy tight edges (c_ij - u_i - v_j == 0)
    tight = np.nonzero(np.abs(w - u[rows] - v[indices]) < 1e-12)[0]
    for e in tight:
        i, j = rows[e], indices[e]
        if col_of_row[i] < 0 and row_of_col[j] < 0:
            col_of_row[i] = j
            row_of_col[j] = i

    # ---- DB-S3: Dijkstra shortest augmenting path per unmatched row --------
    for i0 in range(n):
        if col_of_row[i0] >= 0:
            continue
        # Dijkstra over rows; dist to columns implicit
        dist_col = np.full(n, INF)
        pred_row_of_col = np.full(n, -1, dtype=np.int64)
        visited_col = np.zeros(n, dtype=bool)
        heap = []
        # seed from row i0
        s, e = indptr[i0], indptr[i0 + 1]
        for t in range(s, e):
            j = indices[t]
            if not np.isfinite(w[t]):
                continue
            nd = w[t] - u[i0] - v[j]
            if nd < dist_col[j]:
                dist_col[j] = nd
                pred_row_of_col[j] = i0
                heapq.heappush(heap, (nd, j))
        found_j = -1
        final_dist = 0.0
        while heap:
            dj, j = heapq.heappop(heap)
            if visited_col[j] or dj > dist_col[j]:
                continue
            visited_col[j] = True
            if row_of_col[j] < 0:
                found_j = j
                final_dist = dj
                break
            # continue through the matched row of column j
            i = row_of_col[j]
            s, e = indptr[i], indptr[i + 1]
            for t in range(s, e):
                j2 = indices[t]
                if visited_col[j2] or not np.isfinite(w[t]):
                    continue
                nd = dj + w[t] - u[i] - v[j2]
                if nd < dist_col[j2] - 1e-15:
                    dist_col[j2] = nd
                    pred_row_of_col[j2] = i
                    heapq.heappush(heap, (nd, j2))
        if found_j < 0:
            # structurally singular for this row: leave for fallback pass
            continue
        # update potentials (Johnson re-weighting)
        upd = visited_col | (np.arange(n) == found_j)
        scl = np.nonzero(upd)[0]
        for j in scl:
            if dist_col[j] <= final_dist:
                v[j] += dist_col[j] - final_dist
        # rows on alternating tree: u_i adjusted so tightness is kept
        # (recompute u for matched rows of updated columns)
        for j in scl:
            i = row_of_col[j]
            if i >= 0:
                # keep c_ij - u_i - v_j == 0 on matching edges
                s_, e_ = indptr[i], indptr[i + 1]
                for t in range(s_, e_):
                    if indices[t] == j:
                        u[i] = w[t] - v[j]
                        break
        u[i0] = 0.0 if not np.isfinite(u[i0]) else u[i0]
        # augment along predecessor chain
        j = found_j
        while True:
            i = pred_row_of_col[j]
            row_of_col[j] = i
            col_of_row[i], j = j, col_of_row[i]
            if j < 0:
                break
        # fix u for the newly matched start row
        s, e = indptr[i0], indptr[i0 + 1]
        for t in range(s, e):
            if indices[t] == col_of_row[i0]:
                u[i0] = w[t] - v[col_of_row[i0]]
                break

    # ---- fallback: complete any unmatched rows/cols arbitrarily ------------
    free_cols = [j for j in range(n) if row_of_col[j] < 0]
    fc = 0
    for i in range(n):
        if col_of_row[i] < 0:
            j = free_cols[fc]
            fc += 1
            col_of_row[i] = j
            row_of_col[j] = i

    # ---- DB-S4: permutation (+ scaling) -------------------------------------
    # new row j should be old row matched to column j
    row_perm = row_of_col.copy()
    if not return_scaling:
        return row_perm
    # I-matrix scaling: r_i = exp(u_i)/a_i ; c_j = exp(v_j)  (Olschowka-
    # Neumaier); returns row/col scale factors for the *original* ordering.
    r_scale = np.exp(u) / rowmax
    c_scale = np.exp(v)
    return row_perm, r_scale, c_scale


# ---------------------------------------------------------------------------
# CM: Cuthill-McKee with the paper's multi-start heuristics
# ---------------------------------------------------------------------------


def symmetrize(csr: CSR) -> CSR:
    """Structure/values of (|A| + |A^T|)/2 (paper: (QA + (QA)^T)/2)."""
    at = csr.transpose()
    rows = np.concatenate([csr.row_ids(), at.row_ids()])
    cols = np.concatenate([csr.indices, at.indices])
    data = np.concatenate([np.abs(csr.data) * 0.5, np.abs(at.data) * 0.5])
    return csr_from_coo(csr.n, rows, cols, data)


def _bfs_cm(
    adj_indptr, adj_indices, deg, start, n
) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Single CM BFS pass; returns (order, level, height, max_level_width).

    Handles disconnected graphs by restarting from the unvisited node of
    minimum degree (each component restarts at level 0).
    """
    order = np.full(n, -1, dtype=np.int64)
    level = np.full(n, -1, dtype=np.int64)
    width = np.zeros(n + 1, dtype=np.int64)
    order[0] = start
    level[start] = 0
    width[0] += 1
    head, tail = 0, 1
    height = 0
    while tail < n:
        if head == tail:  # new component
            rest = np.nonzero(level < 0)[0]
            nxt = rest[np.argmin(deg[rest])]
            order[tail] = nxt
            level[nxt] = 0
            width[0] += 1
            tail += 1
        x = order[head]
        head += 1
        s, e = adj_indptr[x], adj_indptr[x + 1]
        nbrs = adj_indices[s:e]
        fresh = nbrs[level[nbrs] < 0]
        if fresh.size:
            # CM rule: enqueue unvisited neighbors by ascending degree
            fresh = np.unique(fresh)
            fresh = fresh[np.argsort(deg[fresh], kind="stable")]
            lv = level[x] + 1
            level[fresh] = lv
            height = max(height, int(lv))
            width[lv] += fresh.size
            order[tail : tail + fresh.size] = fresh
            tail += fresh.size
    return order, level, height, int(width.max())


def cuthill_mckee(sym: CSR, max_iters: int = 3, reverse: bool = False) -> np.ndarray:
    """CM ordering of a symmetric CSR.  Returns perm: new_idx -> old_idx.

    Paper heuristics (Sec. 3.3): start from the min-degree node; rerun from
    the lowest-degree node of the deepest BFS level; stop when the tree
    height stops increasing or the max level width stops decreasing
    (at most ``max_iters`` CM iterations).
    """
    n = sym.n
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    deg = np.diff(sym.indptr)
    cand = int(np.argmin(deg))
    tried: set[int] = set()
    best = None  # (order, height, width)
    for _ in range(max_iters):
        tried.add(cand)
        order, level, height, width = _bfs_cm(sym.indptr, sym.indices, deg, cand, n)
        if best is not None and height <= best[1] and width >= best[2]:
            break  # no improvement -> terminate (paper heuristic)
        if best is None or height > best[1] or width < best[2]:
            best = (order, height, width)
        # next start: lowest-degree node on the last level, not yet tried
        last = np.nonzero(level == height)[0]
        last = last[np.argsort(deg[last], kind="stable")]
        nxt = next((int(x) for x in last if int(x) not in tried), None)
        if nxt is None:
            rest = [x for x in range(n) if x not in tried]
            if not rest:
                break
            nxt = int(rest[np.argmin(deg[rest])])
        cand = nxt
    order = best[0]
    if reverse:
        order = order[::-1].copy()
    return order


def half_bandwidth(csr: CSR) -> int:
    rows = csr.row_ids()
    nz = csr.data != 0.0
    if not np.any(nz):
        return 0
    return int(np.max(np.abs(rows[nz] - csr.indices[nz])))


def permute_rows(csr: CSR, perm: np.ndarray) -> CSR:
    """Rows reordered: new row i = old row perm[i]."""
    counts = np.diff(csr.indptr)[perm]
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    idx = np.concatenate(
        [np.arange(csr.indptr[p], csr.indptr[p + 1]) for p in perm]
    ) if csr.nnz else np.zeros(0, dtype=np.int64)
    return CSR(indptr=indptr, indices=csr.indices[idx], data=csr.data[idx], n=csr.n)


def permute_symmetric(csr: CSR, perm: np.ndarray) -> CSR:
    """Symmetric permutation: B = A[perm][:, perm]."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(csr.n)
    rp = permute_rows(csr, perm)
    return csr_from_coo(csr.n, rp.row_ids(), inv[rp.indices], rp.data)


def csr_to_band(csr: CSR, k: int) -> np.ndarray:
    """Assemble (N, 2K+1) band storage; entries outside the band dropped."""
    n = csr.n
    band = np.zeros((n, 2 * k + 1))
    rows = csr.row_ids()
    off = csr.indices - rows
    keep = np.abs(off) <= k
    band[rows[keep], off[keep] + k] = csr.data[keep]
    return band


def drop_off(csr: CSR, frac: float) -> Tuple[CSR, int]:
    """Drop smallest-|.|  far-from-diagonal elements, bounded by ``frac``
    of the total absolute mass; returns (new_csr, new_half_bandwidth)."""
    rows = csr.row_ids()
    off = np.abs(csr.indices - rows)
    total = np.abs(csr.data).sum()
    budget = frac * total
    k0 = int(off.max()) if off.size else 0
    # mass per distance
    mass = np.zeros(k0 + 1)
    np.add.at(mass, off, np.abs(csr.data))
    # cumulative mass dropped if we truncate band to K (drop all dist > K)
    dropped = np.concatenate([np.cumsum(mass[::-1])[::-1][1:], [0.0]])
    k_new = k0
    for k in range(k0 + 1):
        if dropped[k] <= budget:
            k_new = k
            break
    keep = off <= k_new
    out = csr_from_coo(csr.n, rows[keep], csr.indices[keep], csr.data[keep])
    return out, k_new


# ---------------------------------------------------------------------------
# Third-stage reordering (Sec. 4.3.2): per-partition CM
# ---------------------------------------------------------------------------


def third_stage(
    band: np.ndarray, k: int, p: int, part_size: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-partition CM reordering of the banded matrix.

    ``band``: (N_pad, 2K+1) with N_pad = p * part_size.
    Returns (global_perm, k_per_partition) where global_perm is the
    concatenation of intra-partition permutations (new -> old, global ids)
    and k_per_partition[i] is the half bandwidth of partition i after its
    local reordering.
    """
    n_pad = band.shape[0]
    assert n_pad == p * part_size
    perm = np.empty(n_pad, dtype=np.int64)
    k_i = np.zeros(p, dtype=np.int64)
    for i in range(p):
        lo, hi = i * part_size, (i + 1) * part_size
        # extract diagonal block as CSR
        rows_l, cols_l, vals = [], [], []
        for j in range(2 * k + 1):
            r = np.arange(lo, hi)
            c = r - k + j
            ok = (c >= lo) & (c < hi) & (band[lo:hi, j] != 0.0)
            rows_l.append(r[ok] - lo)
            cols_l.append(c[ok] - lo)
            vals.append(band[lo:hi, j][ok])
        block = csr_from_coo(
            part_size,
            np.concatenate(rows_l),
            np.concatenate(cols_l),
            np.concatenate(vals),
        )
        local = cuthill_mckee(symmetrize(block))
        perm[lo:hi] = local + lo
        k_i[i] = half_bandwidth(permute_symmetric(block, local))
    return perm, k_i
