"""SaP::TPU high-level solver API.

``solve_banded``  : dense banded systems (paper Sec. 2.1 / 4.1).
``solve_sparse``  : sparse systems via DB + CM reordering, drop-off and the
                    sparse->dense-banded fallback (paper Sec. 2.2 / 4.3).

The solver is a Krylov method (BiCGStab(2), or CG for SPD systems)
preconditioned by the split-and-parallelize factorization:

  * variant "D" (decoupled): block-diagonal solve only.
  * variant "C" (coupled):   truncated-SPIKE correction (Sec. 2.1).

Semantics mirror the paper: the Krylov matvec always uses the *original*
(reordered) matrix; drop-off and the banded approximation only affect the
preconditioner.  Mixed precision (Sec. 3.1): the preconditioner is factored
and applied in ``precond_dtype`` (float32 default, bfloat16 on TPU) while
the outer Krylov iteration runs in the dtype of the inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import reorder as reorder_mod
from .banded import (
    band_matvec,
    band_to_block_tridiag,
    pad_banded,
    padded_partition_size,
)
from .block_lu import DEFAULT_BOOST
from .krylov import KrylovResult, bicgstab2, cg
from .spike import build_preconditioner


@dataclasses.dataclass
class SaPOptions:
    p: int = 8  # number of partitions
    variant: str = "C"  # "C" coupled | "D" decoupled
    tol: float = 1e-10
    maxiter: int = 500
    boost_eps: float = DEFAULT_BOOST
    precond_dtype: str = "float32"
    use_cg: bool = False  # CG for SPD systems
    # sparse front-end (Sec. 2.2)
    use_db: bool = True  # diagonal-boosting reordering
    use_cm: bool = True  # bandwidth-reducing reordering
    third_stage: bool = False  # per-partition CM (Sec. 4.3.2)
    drop_tol: float = 0.0  # element drop-off fraction (0 = keep all)


@dataclasses.dataclass
class SaPSolution:
    x: np.ndarray | jax.Array
    iterations: float
    resnorm: float
    converged: bool
    k: int  # half bandwidth used by the preconditioner
    info: dict


def _precond_dtype(opts: SaPOptions):
    return {"float32": jnp.float32, "float64": jnp.float64, "bfloat16": jnp.bfloat16}[
        opts.precond_dtype
    ]


def _krylov_solve(
    matvec: Callable[[jax.Array], jax.Array],
    b_pad: jax.Array,
    band_pc: jax.Array,
    k: int,
    opts: SaPOptions,
):
    """Factor the SaP preconditioner from ``band_pc`` and run Krylov."""
    bt = band_to_block_tridiag(band_pc, max(k, 1), opts.p)
    pc = build_preconditioner(
        bt,
        variant=opts.variant,
        boost_eps=opts.boost_eps,
        precond_dtype=_precond_dtype(opts),
    )
    n_pad_pc = bt.n_pad

    def precond(r):
        rp = jnp.concatenate(
            [r, jnp.zeros((n_pad_pc - r.shape[0],), r.dtype)]
        ) if r.shape[0] != n_pad_pc else r
        z = pc.apply(rp)
        return z[: r.shape[0]]

    solver = cg if opts.use_cg else bicgstab2
    res: KrylovResult = solver(
        matvec, b_pad, precond=precond, tol=opts.tol, maxiter=opts.maxiter
    )
    return res, pc


def solve_banded(
    band: jax.Array,
    b: jax.Array,
    opts: Optional[SaPOptions] = None,
) -> SaPSolution:
    """Solve a dense banded system given in (N, 2K+1) band storage."""
    opts = opts or SaPOptions()
    band = jnp.asarray(band)
    b = jnp.asarray(b)
    n, w = band.shape
    k = (w - 1) // 2

    res, pc = _krylov_solve(
        lambda x: band_matvec(band, x), b, band, k, opts
    )
    return SaPSolution(
        x=res.x,
        iterations=float(res.iterations),
        resnorm=float(res.resnorm),
        converged=bool(res.converged),
        k=k,
        info={"variant": pc.variant, "p": opts.p},
    )


def _csr_matvec_fn(csr) -> Callable[[jax.Array], jax.Array]:
    rows = jnp.asarray(csr.row_ids())
    cols = jnp.asarray(csr.indices)
    data = jnp.asarray(csr.data, dtype=jnp.float32)
    n = csr.n

    def matvec(x):
        return jax.ops.segment_sum(
            data.astype(x.dtype) * x[cols], rows, num_segments=n
        )

    return matvec


def solve_sparse(
    a_csr,
    b: np.ndarray,
    opts: Optional[SaPOptions] = None,
) -> SaPSolution:
    """Solve a sparse system (CSR-like) via the reorder + banded pipeline.

    Pipeline (paper Fig. 3.1): DB reordering (T_DB) -> CM reordering (T_CM)
    -> optional drop-off (T_Drop) -> banded assembly (T_Asmbl) -> SaP
    factorization + Krylov (T_LU .. T_Kry) -> un-permute.
    """
    opts = opts or SaPOptions()
    info: dict = {}

    csr = reorder_mod.to_csr(a_csr)
    n = csr.n
    b = np.asarray(b, dtype=np.float64)

    # --- stage 1: diagonal boosting (row permutation) ----------------------
    if opts.use_db:
        row_perm = reorder_mod.diagonal_boosting(csr)
        csr = reorder_mod.permute_rows(csr, row_perm)
        b_r = b[row_perm]
        info["db"] = True
    else:
        b_r = b
        info["db"] = False

    # --- stage 2: CM bandwidth reduction (symmetric permutation) -----------
    if opts.use_cm:
        sym_perm = reorder_mod.cuthill_mckee(reorder_mod.symmetrize(csr))
        csr = reorder_mod.permute_symmetric(csr, sym_perm)
        b_r = b_r[sym_perm]
        info["cm"] = True
    else:
        sym_perm = np.arange(n)
        info["cm"] = False

    k_full = reorder_mod.half_bandwidth(csr)
    info["k_after_reorder"] = k_full

    # --- stage 3: optional drop-off (preconditioner only) ------------------
    csr_pc = csr
    k = k_full
    if opts.drop_tol > 0.0:
        csr_pc, k = reorder_mod.drop_off(csr, opts.drop_tol)
        info["k_after_drop"] = k
    k = max(k, 1)

    # --- stage 4: banded assembly + solve -----------------------------------
    band_pc = reorder_mod.csr_to_band(csr_pc, k)
    dtype = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    b_j = jnp.asarray(b_r, dtype=dtype)
    matvec = _csr_matvec_fn(csr)
    res, pc = _krylov_solve(matvec, b_j, jnp.asarray(band_pc, dtype), k, opts)

    # --- un-permute ----------------------------------------------------------
    x_r = np.asarray(res.x)
    x = np.empty_like(x_r)
    x[sym_perm] = x_r
    return SaPSolution(
        x=x,
        iterations=float(res.iterations),
        resnorm=float(res.resnorm),
        converged=bool(res.converged),
        k=k,
        info={**info, "variant": pc.variant, "p": opts.p},
    )
