"""SaP::TPU solver API: the plan / factor / solve lifecycle.

The paper's economics (Fig. 3.1) are: pay once for the expensive stages --
DB reordering (T_DB), CM reordering (T_CM), drop-off (T_Drop), banded
assembly (T_Asmbl) and the split block-LU + SPIKE factorization (T_LU) --
then amortize them over a cheap preconditioned Krylov iteration per
right-hand side (T_Kry).  The public API mirrors that lifecycle:

1. ``plan(A, opts) -> SaPPlan``
       Host-side analysis.  Accepts a :class:`~repro.core.operators.
       LinearOperator`, a host CSR / scipy matrix, or a dense square
       array; band storage goes through :func:`plan_banded`.  Computes the
       DB/CM permutations, drop-off, bandwidth, and the preconditioner
       band exactly once; permutations become part of the plan.

2. ``factor(plan) -> SaPFactorization``
       Device-side block-LU + truncated-SPIKE coupling (paper Sec. 2.1).
       The result is a registered JAX pytree: it can be passed through
       ``jax.jit`` boundaries, stored, and reused across any number of
       right-hand sides.

3. ``factorization.solve(b)`` / ``factorization.solve_many(B)``
       Pure JAX, jit-cached, vmap-compatible.  ``solve`` takes one RHS of
       shape (N,); ``solve_many`` takes (N, R) and runs an independent
       Krylov iteration per column (converged columns freeze while
       stragglers iterate).  Permutations are applied and undone inside.

The Krylov matvec always uses the *original* (reordered) matrix; drop-off
and the banded approximation only affect the preconditioner.  Mixed
precision (Sec. 3.1): the preconditioner is factored and applied in
``opts.precond_dtype`` while the outer iteration runs in the dtype of the
input RHS (override with ``opts.iter_dtype``).

``solve_banded`` and ``solve_sparse`` remain as thin one-shot wrappers for
backwards compatibility.  They re-run the whole pipeline on every call and
are **deprecated** for repeated solves -- use the lifecycle above when the
operator is reused.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.trace import span
from . import reorder as reorder_mod
from .banded import band_to_block_tridiag, diag_dominance_factor
from .block_lu import DEFAULT_BOOST
from .krylov import KrylovResult, _bicgstab2_impl, _cg_impl, _refine_impl
from .operators import (
    BandedOperator,
    CsrOperator,
    LinearOperator,
    require_square_dense,
)
from .spike import SaPPreconditioner, build_preconditioner


@dataclasses.dataclass
class SaPOptions:
    """Solver configuration: partitioning, variant, tolerances, dtypes."""

    p: int = 8  # number of partitions
    # "C" coupled (truncated SPIKE) | "D" decoupled | "E" exact reduced
    # system | "auto" (C when the preconditioner band is diagonally
    # dominant, d >= 1, else E -- paper Sec. 2.1.1 guidance).  Resolution
    # happens at factor() time from the planned preconditioner band.
    variant: str = "C"
    tol: float = 1e-10
    maxiter: int = 500
    # Tolerance on the *true* relative residual ||b - A x|| / ||b|| a
    # result must meet before a ``converged`` claim is trusted: the Krylov
    # loop controls the preconditioned residual, and an inexact
    # preconditioner can meet ``tol`` while the true residual is large
    # (misconvergence).  None means 10 * tol.  Consumed by the serving
    # guard (SolverEngine / AsyncSolverService), which escalates or
    # demotes ``converged`` when the check fails; the core solve paths
    # always report ``true_resnorm`` so callers can apply their own check.
    check_true_residual: Optional[float] = None
    boost_eps: float = DEFAULT_BOOST
    precond_dtype: str = "float32"
    iter_dtype: Optional[str] = None  # Krylov dtype; None = follow the RHS
    use_cg: bool = False  # CG for SPD systems
    # Outer solver: "bicgstab2" | "cg" | "refine" (preconditioned iterative
    # refinement -- the mixed-precision play: factor in precond_dtype=f32,
    # refine in iter_dtype=f64 to full f64 accuracy) | "auto" (= "cg" when
    # use_cg else "bicgstab2"; use_cg remains as the legacy spelling).
    solver: str = "auto"
    # Fused factor+spike megakernel: "on" | "off" | "auto" (fused on the
    # compiled Pallas path, kernel sequence elsewhere).  See
    # repro.kernels.fused_spike; resolved at factor() time.
    fused_factor: str = "auto"
    # reduced-system solver for variant "E": "chain" = sequential btf/bts
    # sweep over the (P-1)-interface chain, "bcr" = log-depth block cyclic
    # reduction, "auto" = bcr once the chain is long enough to amortize it.
    reduced_solver: str = "auto"
    # sparse front-end (Sec. 2.2)
    use_db: bool = True  # diagonal-boosting reordering
    use_cm: bool = True  # bandwidth-reducing reordering
    third_stage: bool = False  # per-partition CM (Sec. 4.3.2)
    drop_tol: float = 0.0  # element drop-off fraction (0 = keep all)
    # Record the per-sweep Krylov residual history (observability).  A
    # solve-time knob only: it never enters the factorization pytree or any
    # cache key, so flipping it cannot fragment the engine's LRU or change
    # the compiled history-free executables.
    record_history: bool = False


@dataclasses.dataclass
class SaPSolution:
    """Legacy one-shot result (``solve_banded`` / ``solve_sparse``)."""

    x: np.ndarray | jax.Array
    iterations: float
    resnorm: float
    converged: bool
    k: int  # half bandwidth used by the preconditioner
    info: dict
    true_resnorm: float = float("nan")  # ||b - A x|| / ||b||, unpreconditioned


class SaPSolveResult(NamedTuple):
    """Result of a lifecycle solve; a pytree of device arrays.

    For ``solve_many``, ``x`` is (N, R) and the per-RHS diagnostics
    (``iterations`` / ``resnorm`` / ``converged``) are (R,).  ``d_factor``
    is the degree of diagonal dominance of the preconditioner band
    (paper Eq. 2.11, a scalar shared by all RHS) -- the quantity that
    drives the ``variant="auto"`` policy; the resolved variant itself is
    static metadata, available as ``factorization.variant``.

    Residual semantics: ``converged`` / ``resnorm`` are statements about
    the *preconditioned* residual ``M^-1 (b - A x)`` -- the quantity the
    Krylov iteration drives below ``tol``.  ``true_resnorm`` is the
    unpreconditioned ``||b - A x|| / ||b||`` recomputed at exit against
    the operator actually solved; when the preconditioner is inexact
    (e.g. a structurally-degraded padded embedding) the two can disagree,
    and ``true_resnorm`` is the one that measures answer quality.
    """

    x: jax.Array
    iterations: jax.Array
    resnorm: jax.Array
    converged: jax.Array
    true_resnorm: Optional[jax.Array] = None
    d_factor: Optional[jax.Array] = None
    # (maxiter,) per-sweep preconditioned residuals, NaN-padded -- or
    # (R, maxiter) for solve_many.  None unless record_history was requested.
    history: Optional[jax.Array] = None


def _precond_dtype(opts: SaPOptions):
    return {"float32": jnp.float32, "float64": jnp.float64, "bfloat16": jnp.bfloat16}[
        opts.precond_dtype
    ]


def _resolve_iter_dtype(b_dtype, iter_dtype: Optional[str]):
    """Krylov iteration dtype: explicit option > RHS dtype > canonical float.

    Never silently requests float64 in a non-x64 session (jax would
    truncate it anyway); integer/bool RHS promote to the canonical float.
    """
    x64 = jax.config.read("jax_enable_x64")
    if iter_dtype is not None:
        dt = np.dtype(iter_dtype)
    elif jnp.issubdtype(b_dtype, jnp.floating):
        dt = np.dtype(b_dtype)
    else:
        dt = np.dtype(np.float64 if x64 else np.float32)
    if dt == np.dtype(np.float64) and not x64:
        dt = np.dtype(np.float32)
    return dt


# ---------------------------------------------------------------------------
# Stage 1: plan (host-side analysis; runs the reordering pipeline once)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SaPPlan:
    """Host-side analysis result: operator + permutations + precond band.

    op      : reordered operator the Krylov matvec uses
    band_pc : (N, 2K+1) preconditioner band (post drop-off), device array
    k       : preconditioner half bandwidth
    b_perm  : RHS permutation (None = identity), ``b_r = b[b_perm]``
    x_perm  : unknown un-permutation (None = identity), ``x = x_r[x_perm]``
    opts    : solver options the factorization will inherit
    info    : stage diagnostics (db/cm flags, k_after_reorder, ...)
    """

    op: LinearOperator
    band_pc: jax.Array
    k: int
    n: int
    b_perm: Optional[np.ndarray]
    x_perm: Optional[np.ndarray]
    opts: SaPOptions
    info: dict


def plan_banded(band, opts: Optional[SaPOptions] = None) -> SaPPlan:
    """Plan for a dense banded system in (N, 2K+1) band storage.

    No reordering: the matrix is already banded (paper Sec. 4.1); the band
    itself is the preconditioner matrix.
    """
    opts = opts or SaPOptions()
    op = band if isinstance(band, BandedOperator) else BandedOperator.from_band(band)
    return SaPPlan(
        op=op,
        band_pc=op.band,
        k=op.k,
        n=op.n,
        b_perm=None,
        x_perm=None,
        opts=opts,
        info={"variant": opts.variant, "p": opts.p},
    )


def plan(a, opts: Optional[SaPOptions] = None) -> SaPPlan:
    """Plan for a general operator / sparse matrix (paper Sec. 2.2 / 4.3).

    Runs DB + CM reordering and drop-off once (per ``opts``); the returned
    plan carries the permutations, the reordered operator, and the
    preconditioner band.  Banded operators skip the reordering front end.
    """
    opts = opts or SaPOptions()
    if isinstance(a, BandedOperator):
        return plan_banded(a, opts)
    if isinstance(a, CsrOperator):
        a = a.to_csr()
    elif isinstance(a, (np.ndarray, jax.Array)):
        require_square_dense(a)

    with span("plan", use_db=opts.use_db, use_cm=opts.use_cm) as sp:
        rp = reorder_mod.analyze(
            a, use_db=opts.use_db, use_cm=opts.use_cm, drop_tol=opts.drop_tol
        )
        sp.annotate(n=rp.csr.n, k=rp.k)
    op = CsrOperator.from_csr(rp.csr)
    canonical = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    return SaPPlan(
        op=op,
        band_pc=jnp.asarray(rp.band_pc, canonical),
        k=rp.k,
        n=rp.csr.n,
        b_perm=rp.b_perm,
        x_perm=rp.x_perm,
        opts=opts,
        info={**rp.info, "variant": opts.variant, "p": opts.p},
    )


# ---------------------------------------------------------------------------
# Stage 2: factor (device-side block-LU + SPIKE; returns a reusable handle)
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("op", "pc", "b_perm", "x_perm", "d_factor"),
    meta_fields=("n", "k", "tol", "maxiter", "use_cg", "iter_dtype", "solver"),
)
@dataclasses.dataclass(eq=False)
class SaPFactorization:
    """Reusable SaP factorization handle (a registered JAX pytree).

    Holds the reordered operator, the factored preconditioner, and the
    permutations; ``solve`` / ``solve_many`` are pure JAX and jit-cached,
    so repeated right-hand sides pay only the Krylov iteration.

    ``d_factor`` (degree of diagonal dominance of the preconditioner band,
    paper Eq. 2.11) is carried as a device scalar -- a *data* field, so
    factorizations of different matrices share one compiled solve -- and
    echoed into every :class:`SaPSolveResult`.  The variant actually
    factored (after ``"auto"`` resolution) is ``self.variant``.
    """

    op: LinearOperator
    pc: SaPPreconditioner
    b_perm: Optional[jax.Array]  # int32 (N,) or None (identity)
    x_perm: Optional[jax.Array]  # int32 (N,) or None (identity)
    n: int
    k: int
    tol: float
    maxiter: int
    use_cg: bool
    iter_dtype: Optional[str]
    # resolved outer solver ("bicgstab2" | "cg" | "refine"); never "auto"
    solver: str = "bicgstab2"
    d_factor: Optional[jax.Array] = None  # scalar, Eq. 2.11 estimate

    @property
    def variant(self) -> str:
        """Variant actually factored ("auto" resolved): "C", "D", or "E"."""
        return self.pc.variant

    @property
    def p(self) -> int:
        """Number of partitions in the factorization."""
        return self.pc.p

    @property
    def n_pad(self) -> int:
        """Internal (padded) problem size P*M*K; >= the user's N."""
        return self.pc.p * self.pc.m * self.pc.k

    def solve(self, b: jax.Array, record_history: bool = False) -> SaPSolveResult:
        """Solve A x = b for a single RHS of shape (N,).

        ``record_history=True`` additionally returns the per-sweep Krylov
        residual track on ``result.history`` (a separate jit cache entry;
        the default path's compiled executable is untouched).
        """
        b = jnp.asarray(b)
        if b.ndim != 1:
            raise ValueError(
                f"solve expects a single RHS of shape ({self.n},), got "
                f"{b.shape}; use solve_many for batched (N, R) systems"
            )
        if b.shape[0] != self.n:
            raise ValueError(f"RHS length {b.shape[0]} != operator size {self.n}")
        with span(
            "krylov", n=self.n, k=self.k, p=self.p, variant=self.variant, nrhs=1
        ) as sp:
            res = sp.sync(_solve_one(self, b, record_history=record_history))
        if sp:
            sp.annotate(convergence=_convergence_summary(res))
        return res

    def solve_many(self, b: jax.Array, record_history: bool = False) -> SaPSolveResult:
        """Solve A X = B for B of shape (N, R): one Krylov run per column."""
        b = jnp.asarray(b)
        if b.ndim != 2:
            raise ValueError(
                f"solve_many expects shape ({self.n}, R), got {b.shape}; "
                f"use solve for a single (N,) RHS"
            )
        if b.shape[0] != self.n:
            raise ValueError(f"RHS length {b.shape[0]} != operator size {self.n}")
        with span(
            "krylov",
            n=self.n,
            k=self.k,
            p=self.p,
            variant=self.variant,
            nrhs=int(b.shape[1]),
        ) as sp:
            res = sp.sync(_solve_many(self, b, record_history=record_history))
        if sp:
            sp.annotate(convergence=_convergence_summary(res))
        return res


def resolve_solver(solver: str, use_cg: bool) -> str:
    """Resolve ``SaPOptions.solver`` to a concrete outer solver name.

    ``"auto"`` honors the legacy ``use_cg`` flag; explicit names win over
    it.  The result is what ``SaPFactorization.solver`` carries.
    """
    if solver == "auto":
        return "cg" if use_cg else "bicgstab2"
    if solver not in ("bicgstab2", "cg", "refine"):
        raise ValueError(f"unknown solver {solver!r}")
    return solver


def resolve_variant(variant: str, d_factor: float) -> str:
    """The ``"auto"`` policy: truncated SPIKE needs spike decay, which the
    paper ties to diagonal dominance (Sec. 2.1.1) -- pick the cheap
    truncated variant C for d >= 1, the exact reduced system E otherwise.
    """
    if variant != "auto":
        return variant
    return "C" if d_factor >= 1.0 else "E"


def factor(pl: SaPPlan) -> SaPFactorization:
    """Factor the SaP preconditioner from a plan (T_LU .. T_SPIKE).

    Device-side and done once; the returned handle is reusable across any
    number of ``solve`` / ``solve_many`` calls and jit boundaries.
    ``variant="auto"`` is resolved here from the planned preconditioner
    band's degree of diagonal dominance (C for d >= 1, else E).
    """
    opts = pl.opts
    with span("factor", n=pl.n, k=pl.k, p=opts.p) as sp:
        d_factor = diag_dominance_factor(pl.band_pc)
        variant = resolve_variant(opts.variant, float(d_factor))
        sp.annotate(variant=variant, d_factor=float(d_factor))
        with span("factor.split"):
            bt = band_to_block_tridiag(pl.band_pc, max(pl.k, 1), opts.p)
        pc = build_preconditioner(
            bt,
            variant=variant,
            boost_eps=opts.boost_eps,
            precond_dtype=_precond_dtype(opts),
            reduced_solver=opts.reduced_solver,
            fused=opts.fused_factor,
        )
        sp.sync(pc)
    to_idx = lambda p: None if p is None else jnp.asarray(p, jnp.int32)
    return SaPFactorization(
        op=pl.op,
        pc=pc,
        b_perm=to_idx(pl.b_perm),
        x_perm=to_idx(pl.x_perm),
        n=pl.n,
        k=pl.k,
        tol=opts.tol,
        maxiter=opts.maxiter,
        use_cg=opts.use_cg,
        iter_dtype=opts.iter_dtype,
        solver=resolve_solver(opts.solver, opts.use_cg),
        d_factor=d_factor,
    )


# ---------------------------------------------------------------------------
# Stage 3: solve (pure JAX; jit-cached module-level entry points)
# ---------------------------------------------------------------------------


def _solve_impl(
    fac: SaPFactorization, b: jax.Array, record_history: bool = False
) -> SaPSolveResult:
    """Single-RHS solve body: permute, Krylov, un-permute (all on device)."""
    dt = _resolve_iter_dtype(b.dtype, fac.iter_dtype)
    b = b.astype(dt)
    if fac.b_perm is not None:
        b = b[fac.b_perm]

    n, n_pad = fac.n, fac.n_pad

    def precond(r):
        # named_scope (not a host span): this runs under jit/vmap, and the
        # scope name groups the preconditioner-apply ops in XLA profiles so
        # the in-device precond-vs-matvec split is readable there.
        with jax.named_scope("sap.precond_apply"):
            rp = (
                jnp.concatenate([r, jnp.zeros((n_pad - n,), r.dtype)])
                if n_pad != n
                else r
            )
            return fac.pc.apply(rp)[:n]

    if fac.solver == "refine":
        solver = _refine_impl
    elif fac.solver == "cg" or fac.use_cg:
        solver = _cg_impl
    else:
        solver = _bicgstab2_impl
    with jax.named_scope("sap.krylov"):
        res: KrylovResult = solver(
            fac.op.matvec,
            b,
            precond=precond,
            tol=fac.tol,
            maxiter=fac.maxiter,
            record_history=record_history,
        )
    x = res.x[fac.x_perm] if fac.x_perm is not None else res.x
    # true_resnorm is computed in the solver frame (permuted / padded),
    # but permutations preserve norms and exact identity-padding rows
    # contribute a zero residual, so it equals the original-frame
    # ||b - A x|| / ||b|| of the unpadded, unpermuted system.
    return SaPSolveResult(
        x=x,
        iterations=res.iterations,
        resnorm=res.resnorm,
        converged=res.converged,
        true_resnorm=res.true_resnorm,
        d_factor=fac.d_factor,
        history=res.history,
    )


_solve_one = jax.jit(_solve_impl, static_argnames=("record_history",))


@partial(jax.jit, static_argnames=("record_history",))
def _solve_many(
    fac: SaPFactorization, bmat: jax.Array, record_history: bool = False
) -> SaPSolveResult:
    # d_factor is shared by all RHS (closed over, unbatched): out_axes None
    out_axes = SaPSolveResult(
        x=1, iterations=0, resnorm=0, converged=0, true_resnorm=0,
        d_factor=None,
        history=0 if record_history else None,
    )
    return jax.vmap(
        lambda bi: _solve_impl(fac, bi, record_history), in_axes=1, out_axes=out_axes
    )(bmat)


def _convergence_summary(res: SaPSolveResult) -> dict:
    """Host-side convergence digest for the ``krylov`` span attribute."""
    out = {
        "iterations": float(np.max(np.asarray(res.iterations))),
        "converged": bool(np.all(np.asarray(res.converged))),
        "resnorm": float(np.max(np.asarray(res.resnorm))),
    }
    if res.history is not None:
        hist = np.atleast_2d(np.asarray(res.history))
        firsts, lasts, recorded, stalled = [], [], 0, False
        for row in hist:
            rec = row[~np.isnan(row)]
            recorded = max(recorded, rec.size)
            if rec.size == 0:
                continue
            firsts.append(float(rec[0]))
            lasts.append(float(rec[-1]))
            # Stall heuristic: <10% progress over the last 5 recorded sweeps.
            if rec.size >= 5 and rec[-1] > 0.9 * rec[-5]:
                stalled = True
        out["recorded"] = recorded
        if firsts:
            out["first_resnorm"] = max(firsts)
            out["last_resnorm"] = max(lasts)
        out["stalled"] = bool(stalled and not out["converged"])
    return out


# ---------------------------------------------------------------------------
# Legacy one-shot wrappers (deprecated for repeated solves)
# ---------------------------------------------------------------------------


def _warn_one_shot(name: str, replacement: str) -> None:
    # Python's default "once per location" warning filter dedups this;
    # stacklevel=3 points at the caller of the public wrapper.
    warnings.warn(
        f"{name} re-runs the whole plan/factor pipeline on every call and "
        f"is deprecated; use {replacement} and reuse the handle across "
        f"right-hand sides (repro.core.sap lifecycle API)",
        DeprecationWarning,
        stacklevel=3,
    )


def solve_banded(
    band: jax.Array,
    b: jax.Array,
    opts: Optional[SaPOptions] = None,
) -> SaPSolution:
    """One-shot solve of a dense banded system in (N, 2K+1) band storage.

    Deprecated for repeated solves: this re-plans and re-factors on every
    call.  Use ``factor(plan_banded(band, opts))`` and reuse the handle.
    """
    _warn_one_shot("solve_banded", "factor(plan_banded(band, opts)).solve(b)")
    pl = plan_banded(band, opts)
    fac = factor(pl)
    res = fac.solve(jnp.asarray(b))
    return SaPSolution(
        x=res.x,
        iterations=float(res.iterations),
        resnorm=float(res.resnorm),
        converged=bool(res.converged),
        true_resnorm=float(res.true_resnorm),
        k=fac.k,
        info={
            "variant": fac.variant,
            "variant_requested": pl.opts.variant,
            "reduced_solver": fac.pc.reduced_solver,
            "d_factor": float(fac.d_factor),
            "p": pl.opts.p,
        },
    )


def solve_sparse(
    a_csr,
    b: np.ndarray,
    opts: Optional[SaPOptions] = None,
) -> SaPSolution:
    """One-shot solve of a sparse system via the reorder + banded pipeline.

    Deprecated for repeated solves: this re-runs DB/CM reordering and the
    block-LU factorization on every call.  Use ``factor(plan(a, opts))``
    and reuse the handle across right-hand sides.
    """
    _warn_one_shot("solve_sparse", "factor(plan(a, opts)).solve(b)")
    pl = plan(a_csr, opts)
    fac = factor(pl)
    res = fac.solve(jnp.asarray(np.asarray(b)))
    return SaPSolution(
        x=np.asarray(res.x),
        iterations=float(res.iterations),
        resnorm=float(res.resnorm),
        converged=bool(res.converged),
        true_resnorm=float(res.true_resnorm),
        k=fac.k,
        info={
            **pl.info,
            "variant": fac.variant,
            "variant_requested": pl.opts.variant,
            "reduced_solver": fac.pc.reduced_solver,
            "d_factor": float(fac.d_factor),
            "p": pl.opts.p,
        },
    )
