"""Minimal CSR sparse-matrix container (numpy, host-side).

The paper's reordering stages (DB, CM) are host-side preprocessing in
SaP::GPU as well (hybrid CPU/GPU, Sec. 3.2-3.3); here they are numpy.
The device-side story starts after banded assembly.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int64 column indices
    data: np.ndarray  # (nnz,) float64
    n: int

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row(self, i: int):
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.data[s:e]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n, self.n))
        rows = np.repeat(np.arange(self.n), np.diff(self.indptr))
        out[rows, self.indices] = self.data
        return out

    def row_ids(self) -> np.ndarray:
        return np.repeat(np.arange(self.n), np.diff(self.indptr))

    def transpose(self) -> "CSR":
        rows = self.row_ids()
        order = np.lexsort((rows, self.indices))
        new_rows = self.indices[order]
        new_cols = rows[order]
        new_data = self.data[order]
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(indptr, new_rows + 1, 1)
        indptr = np.cumsum(indptr)
        return CSR(indptr=indptr, indices=new_cols, data=new_data, n=self.n)


def csr_from_dense(a: np.ndarray, tol: float = 0.0) -> CSR:
    n = a.shape[0]
    mask = np.abs(a) > tol
    rows, cols = np.nonzero(mask)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols.astype(np.int64), data=a[rows, cols].astype(np.float64), n=n)


def csr_from_coo(n: int, rows, cols, data) -> CSR:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    data = np.asarray(data, dtype=np.float64)
    order = np.lexsort((cols, rows))
    rows, cols, data = rows[order], cols[order], data[order]
    # combine duplicates
    if len(rows) > 0:
        key = rows * n + cols
        uniq, first = np.unique(key, return_index=True)
        summed = np.add.reduceat(data, first)
        rows = uniq // n
        cols = uniq % n
        data = summed
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols, data=data, n=n)


# ---------------------------------------------------------------------------
# Sparse test-matrix generators (for the paper's Sec. 4.2/4.3 style suites)
# ---------------------------------------------------------------------------


def random_sparse(
    n: int,
    avg_nnz_per_row: float = 6.0,
    d: float = 1.0,
    shuffle: bool = True,
    seed: int = 0,
    structured_band: int | None = None,
) -> CSR:
    """Random sparse matrix with a hidden banded structure.

    Mirrors the provenance of the paper's FE/multibody matrices: a narrow-
    band matrix (e.g. from a 1D/2D stencil) scrambled by a random symmetric
    permutation, so DB/CM reorderings have something to recover.
    ``d`` is the diagonal-dominance degree in the *unscrambled* ordering.
    """
    rng = np.random.default_rng(seed)
    k = structured_band or max(2, int(avg_nnz_per_row) // 2)
    rows, cols, data = [], [], []
    for off in range(1, k + 1):
        keep = rng.random(n - off) < (avg_nnz_per_row / (2.0 * k))
        idx = np.nonzero(keep)[0]
        vals = rng.uniform(-1.0, 1.0, size=idx.shape[0])
        rows.append(idx)
        cols.append(idx + off)
        data.append(vals)
        vals2 = rng.uniform(-1.0, 1.0, size=idx.shape[0])
        rows.append(idx + off)
        cols.append(idx)
        data.append(vals2)
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    data = np.concatenate(data)
    # diagonal with dominance d
    off_abs = np.zeros(n)
    np.add.at(off_abs, rows, np.abs(data))
    diag = d * np.maximum(off_abs, 1e-3) * np.where(rng.random(n) < 0.5, -1.0, 1.0)
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    data = np.concatenate([data, diag])
    if shuffle:
        perm = rng.permutation(n)
        rows, cols = perm[rows], perm[cols]
    return csr_from_coo(n, rows, cols, data)
