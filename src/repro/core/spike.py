"""SPIKE machinery: truncated spikes, reduced system, SaP preconditioner.

Implements paper Sec. 2.1:

  * right-spike bottom blocks   V_i^(b) = Sinv_i[M-1] @ B_i          (2.2a)
  * left-spike top blocks       W_{i+1}^(t) via the UL factorization (2.2c)
  * the truncated reduced system (2.9):
        Rbar_i               = I - W_{i+1}^(t) V_i^(b)
        Rbar_i xt_{i+1}^(t)  = g_{i+1}^(t) - W_{i+1}^(t) g_i^(b)
        xt_i^(b)             = g_i^(b) - V_i^(b) xt_{i+1}^(t)
  * the final decoupled solves (2.10).

Three preconditioner variants (paper Sec. 2.1.1):
  * SaP-D  ("decoupled"): z = D^{-1} r, one block solve.
  * SaP-C  ("coupled"):   block solve + truncated-spike correction +
                          second block solve.
  * SaP-E  ("exact"):     block solve + *exact* reduced-system correction +
                          second block solve.  The truncation in (2.9) rests
                          on spike decay, which requires diagonal dominance
                          (d >= 1, Eq. 2.11); SaP-E instead assembles the
                          full (P-1)-interface reduced system from whole
                          spikes -- a block-tridiagonal chain of (2K x 2K)
                          blocks -- and factors it with the same btf/bts
                          stack used for the partitions (recursively, so
                          the Pallas kernel dispatch covers it too).  The
                          apply is then an exact solve of the banded
                          preconditioner matrix, robust for d < 1 at the
                          cost of the extra O(P K^3) reduced factor.

Reduced system (exact; unknowns y_i = [x_i^(b); x_{i+1}^(t)], i = 0..P-2):

    [ I            V_i^(b) ]        [ W_i^(b) 0 ]        [ 0  0          ]
    [ W_{i+1}^(t)  I       ] y_i  + [ 0       0 ] y_{i-1} + [ 0  V_{i+1}^(t) ] y_{i+1}
        = [ g_i^(b); g_{i+1}^(t) ]

where V_i = A_i^{-1}[0;..;B_i] and W_i = A_i^{-1}[C_i;0;..] are the whole
spikes (their top/bottom K x K blocks appear above).  Truncating the
off-diagonal terms recovers (2.9).

Every block inversion here goes through
:func:`repro.core.block_lu.gj_inverse`, whose structural-zero exemption
keeps identity-padded slots (shape bucketing) exactly identity: the
coupling blocks B/C of a padded embedding are zero on padded rows, so the
spikes -- and hence the reduced system -- of blkdiag(A, I) decouple
exactly instead of picking up boosted ``1/thr`` perturbations.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .banded import BlockTridiag
from .block_lu import (
    DEFAULT_BOOST,
    BTFactors,
    FusedSpikeFactors,
    btf_chain,
    btf_ref,
    btf_ul_ref,
    bts_chain,
    bts_ref,
    fused_factor_spike_ref,
    gj_inverse,
)
from ..obs.trace import span
from .cyclic_reduction import (
    BCRFactors,
    bcr_factor,
    bcr_solve,
    resolve_reduced_solver,
)


def _flip_rows(x: jax.Array) -> jax.Array:
    return x[..., ::-1, :]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "lu", "b_cpl", "c_cpl", "v_bot", "w_top", "rbar_inv", "red_lu",
        "red_bcr",
    ),
    meta_fields=("variant", "p", "m", "k", "impl", "reduced_solver", "fused"),
)
@dataclasses.dataclass
class SaPPreconditioner:
    """Factored SaP preconditioner ('C' coupled, 'D' decoupled, 'E' exact).

    All factor arrays may be stored in a lower precision than the Krylov
    iteration (paper Sec. 3.1 "Mixed Precision Strategy").
    """

    variant: str  # "C" | "D" | "E"
    lu: BTFactors  # factors of diag(A_1..A_P)
    b_cpl: jax.Array  # (P-1, K, K)
    c_cpl: jax.Array  # (P-1, K, K)
    v_bot: Optional[jax.Array]  # (P-1, K, K)  V_i^(b)
    w_top: Optional[jax.Array]  # (P-1, K, K)  W_{i+1}^(t)
    rbar_inv: Optional[jax.Array]  # (P-1, K, K)  inv(I - W V)
    red_lu: Optional[BTFactors]  # factors of the exact (P-1, 2K) reduced chain
    red_bcr: Optional[BCRFactors]  # log-depth BCR factors of the same chain
    p: int
    m: int
    k: int
    impl: str = "jnp"  # kernel dispatch: "jnp" | "interpret" | "pallas"
    # resolved reduced-chain solver for variant E: "chain" (sequential
    # btf/bts sweep) or "bcr" (log-depth cyclic reduction); "none" otherwise
    reduced_solver: str = "none"
    # True when the factor+spike stage ran as the fused single-pass
    # megakernel instead of the btf -> UL -> bts kernel sequence
    fused: bool = False

    def apply(self, r: jax.Array) -> jax.Array:
        """Apply M^{-1} to a (padded) flat residual of length P*M*K."""
        dtype = self.lu.sinv.dtype
        rb = r.astype(dtype).reshape(self.p, self.m, self.k, -1)
        if self.variant == "D":
            z = _bts(self.lu, rb, self.impl)
        elif self.variant == "E":
            z = _apply_exact(self, rb)
        else:
            z = _apply_coupled(self, rb)
        return z.reshape(r.shape).astype(r.dtype)


def _bts(factors, b, impl):
    """Solve through the kernel dispatch layer (lazy import: no cycles)."""
    if impl == "jnp":
        return bts_ref(factors, b)
    from repro.kernels import ops as kops

    return kops.block_tridiag_solve(factors, b, impl=impl)


def _btf(d, e, f, boost_eps, impl):
    if impl == "jnp":
        return btf_ref(d, e, f, boost_eps)
    from repro.kernels import ops as kops

    return kops.block_tridiag_factor(d, e, f, boost_eps, impl=impl)


def _btf_chain(d, e, f, boost_eps, impl):
    """Factor one block-tridiag chain (M, K, K) through the same dispatch."""
    if impl == "jnp":
        return btf_chain(d, e, f, boost_eps)
    from repro.kernels import ops as kops

    return kops.block_tridiag_factor_chain(d, e, f, boost_eps, impl=impl)


def _bts_chain(factors, b, impl):
    if impl == "jnp":
        return bts_chain(factors, b)
    from repro.kernels import ops as kops

    return kops.block_tridiag_solve_chain(factors, b, impl=impl)


def _bcr_factor(d, e, f, boost_eps, impl):
    """Log-depth chain factor through the same dispatch (ref/interpret/pallas)."""
    if impl == "jnp":
        return bcr_factor(d, e, f, boost_eps)
    from repro.kernels import ops as kops

    return kops.bcr_factor(d, e, f, boost_eps, impl=impl)


def _bcr_solve(factors, b, impl):
    if impl == "jnp":
        return bcr_solve(factors, b)
    from repro.kernels import ops as kops

    return kops.bcr_solve(factors, b, impl=impl)


def _fused_factor_spike(d, e, f, b_cpl, c_cpl, boost_eps, impl):
    """Fused factor+spike megakernel through the same dispatch."""
    if impl == "jnp":
        return fused_factor_spike_ref(d, e, f, b_cpl, c_cpl, boost_eps)
    from repro.kernels import ops as kops

    return kops.fused_factor_spike(d, e, f, b_cpl, c_cpl, boost_eps, impl=impl)


def resolve_fused(fused, impl: str) -> bool:
    """Resolve the ``fused_factor`` knob: ``"auto"`` means fused on the
    compiled kernel path (where the VMEM carries actually avoid HBM
    round trips) and the kernel-sequence formulation elsewhere."""
    if fused in (True, "on"):
        return True
    if fused in (None, False, "off"):
        return False
    if fused == "auto":
        return impl == "pallas"
    raise ValueError(f"unknown fused_factor setting {fused!r}")


def _apply_coupled(pc: SaPPreconditioner, rb: jax.Array) -> jax.Array:
    # 1) g = D^{-1} r
    g = _bts(pc.lu, rb, pc.impl)  # (P, M, K, R)
    g_top = g[:, 0]  # (P, K, R)
    g_bot = g[:, -1]  # (P, K, R)

    # 2) reduced-system correction per interface i = 0..P-2   (eq. 2.9)
    rhs = g_top[1:] - pc.w_top @ g_bot[:-1]  # (P-1, K, R)
    xt_top = pc.rbar_inv @ rhs  # xt_{i+1}^(t)
    xt_bot = g_bot[:-1] - pc.v_bot @ xt_top  # xt_i^(b)

    # 3) final solves (eq. 2.10): subtract coupling contributions
    top_corr = pc.c_cpl @ xt_bot  # into partitions 1..P-1, top block
    bot_corr = pc.b_cpl @ xt_top  # into partitions 0..P-2, bottom block
    rb2 = rb
    rb2 = rb2.at[1:, 0].add(-top_corr)
    rb2 = rb2.at[:-1, -1].add(-bot_corr)
    return _bts(pc.lu, rb2, pc.impl)


def _apply_exact(pc: SaPPreconditioner, rb: jax.Array) -> jax.Array:
    """SaP-E apply: an exact solve of the banded preconditioner matrix."""
    # 1) g = D^{-1} r
    g = _bts(pc.lu, rb, pc.impl)  # (P, M, K, R)

    # 2) exact reduced system on the interface unknowns y_i = [x_i^(b);
    #    x_{i+1}^(t)]; the RHS is just the interface slices of g (the spike
    #    blocks live in the factored chain, not in the RHS).
    h = jnp.concatenate([g[:-1, -1], g[1:, 0]], axis=1)  # (P-1, 2K, R)
    if pc.reduced_solver == "bcr":
        y = _bcr_solve(pc.red_bcr, h, pc.impl)
    else:
        y = _bts_chain(pc.red_lu, h, pc.impl)
    xt_bot = y[:, : pc.k]  # x_i^(b),     i = 0..P-2
    xt_top = y[:, pc.k :]  # x_{i+1}^(t), i = 0..P-2

    # 3) final solves (eq. 2.10), now with exact interface values
    rb2 = rb.at[1:, 0].add(-(pc.c_cpl @ xt_bot))
    rb2 = rb2.at[:-1, -1].add(-(pc.b_cpl @ xt_top))
    return _bts(pc.lu, rb2, pc.impl)


def _reduced_interface_system(v_bot, v_top, w_top, w_bot):
    """Assemble the exact (P-1)-interface block-tridiag chain (2K blocks).

    Inputs are the four corner blocks of the whole spikes, each (P-1, K, K):
    v_bot/v_top index right spikes of partitions 0..P-2, w_top/w_bot left
    spikes of partitions 1..P-1.  Returns (d, e, f) of shape
    (P-1, 2K, 2K); e[0] / f[P-2] are unused by the factorization.
    """
    dtype = v_bot.dtype
    q, k, _ = v_bot.shape  # q = P-1 interfaces
    eye = jnp.broadcast_to(jnp.eye(k, dtype=dtype), (q, k, k))
    zero = jnp.zeros((q, k, k), dtype)

    def blk2(tl, tr, bl, br):
        top = jnp.concatenate([tl, tr], axis=-1)
        bot = jnp.concatenate([bl, br], axis=-1)
        return jnp.concatenate([top, bot], axis=-2)

    # y_{i-1} contributes W_i^(b) x_{i-1}^(b); y_{i+1} contributes
    # V_{i+1}^(t) x_{i+2}^(t) (see module docstring).
    shift_dn = lambda x: jnp.concatenate([jnp.zeros_like(x[:1]), x[:-1]], 0)
    shift_up = lambda x: jnp.concatenate([x[1:], jnp.zeros_like(x[:1])], 0)
    rd = blk2(eye, v_bot, w_top, eye)
    re = blk2(shift_dn(w_bot), zero, zero, zero)
    rf = blk2(zero, zero, zero, shift_up(v_top))
    return rd, re, rf


def build_preconditioner(
    bt: BlockTridiag,
    variant: str = "C",
    boost_eps: float = DEFAULT_BOOST,
    precond_dtype=jnp.float32,
    impl: str = "jnp",
    spike_mode: str = "ul",
    reduced_solver: str = "auto",
    fused: str | bool = "off",
) -> SaPPreconditioner:
    """Factor the SaP preconditioner from block-tridiagonal partitions.

    spike_mode:
      * "ul"   -- paper Sec. 2.1 fast path: V^(b) from the bottom of the LU
                  factors, W^(t) from a UL factorization (top only).
      * "full" -- compute the *entire* spikes by full solves and take the
                  needed blocks.  This is the paper's third-stage-reordering
                  path (Sec. 2.2.1: per-partition reordering "renders the UL
                  factorization superfluous" and mandates whole spikes).
      Variant "E" always uses whole spikes (it needs all four corner
      blocks), so ``spike_mode`` is ignored there.

    reduced_solver (variant "E" only; carried on the returned pytree and
    echoed into ``SaPSolution.info``):
      * "chain" -- sequential btf/bts sweep over the (P-1)-interface chain
                   (O(P) dependent steps).
      * "bcr"   -- block cyclic reduction: O(log2 P) parallel levels
                   (``repro.core.cyclic_reduction``), same kernel dispatch.
      * "auto"  -- "bcr" once the chain is long enough to amortize the
                   log-depth machinery, else "chain".

    fused (``"on"`` / ``"off"`` / ``"auto"``; bools accepted): run the
    factor AND spike-corner extraction as ONE fused pass
    (:func:`repro.kernels.ops.fused_factor_spike`) instead of the
    btf -> UL-btf -> bts kernel sequence.  ``"auto"`` resolves to fused on
    the compiled kernel path (``impl="pallas"``), where the UL recurrence
    and spike carries stay in VMEM instead of round-tripping HBM.  The
    fused pass is UL-based, so it applies to variants C/E with P > 1 under
    ``spike_mode="ul"``; it produces bit-identical ``lu`` / ``v_bot`` /
    ``w_top`` and algebraically equal ``v_top`` / ``w_bot`` (forward
    carries instead of whole-spike back-substitution).
    """
    if variant not in ("C", "D", "E"):
        raise ValueError(f"unknown SaP variant {variant!r}")
    if spike_mode not in ("ul", "full"):
        raise ValueError(f"unknown spike_mode {spike_mode!r}")
    reduced_solver = (
        resolve_reduced_solver(reduced_solver, bt.p - 1)
        if variant == "E" and bt.p > 1
        else "none"
    )
    use_fused = (
        resolve_fused(fused, impl)
        and variant in ("C", "E")
        and spike_mode == "ul"
        and bt.p > 1
    )
    d = bt.d.astype(precond_dtype)
    e = bt.e.astype(precond_dtype)
    f = bt.f.astype(precond_dtype)
    b_cpl = bt.b_cpl.astype(precond_dtype)
    c_cpl = bt.c_cpl.astype(precond_dtype)

    v_bot = w_top = rbar_inv = red_lu = red_bcr = None
    v_top = w_bot = None
    # Spans degrade to no-ops under jit/vmap tracing (the batched factor
    # stages call this inside vmap), so host timing only covers eager calls.
    if use_fused:
        with span(
            "factor.fused", p=bt.p, m=bt.m, k=bt.k, variant=variant, impl=impl
        ) as sp:
            fs: FusedSpikeFactors = _fused_factor_spike(
                d, e, f, b_cpl, c_cpl, boost_eps, impl
            )
            lu = fs.lu
            v_bot, w_top = fs.v_bot, fs.w_top
            v_top, w_bot = fs.v_top, fs.w_bot
            sp.sync((v_bot, w_top))
    else:
        with span("factor.lu", p=bt.p, m=bt.m, k=bt.k, impl=impl) as sp:
            lu = sp.sync(_btf(d, e, f, boost_eps, impl))

    if variant in ("C", "E") and bt.p > 1:
        if not use_fused:
            with span("factor.spike", variant=variant, mode=spike_mode) as sp:
                if variant == "C" and spike_mode == "ul":
                    # V_i^(b) = Sinv_i[M-1] @ B_i  for i = 0..P-2
                    v_bot = lu.sinv[:-1, -1] @ b_cpl
                    # W_{i+1}^(t) from the UL factorization of partitions
                    # 1..P-1
                    ul = btf_ul_ref(d, e, f, boost_eps)
                    w_top = _flip_rows(ul.sinv[1:, -1] @ _flip_rows(c_cpl))
                else:
                    # whole right spikes: A_i V_i = [0;..;B_i], keep corners
                    rhs_b = jnp.zeros((bt.p, bt.m, bt.k, bt.k), precond_dtype)
                    rhs_b = rhs_b.at[:-1, -1].set(b_cpl)
                    v_full = _bts(lu, rhs_b, impl)
                    v_bot = v_full[:-1, -1]
                    v_top = v_full[:-1, 0]
                    # whole left spikes: A_{i+1} W_{i+1} = [C_{i+1};0;..]
                    rhs_c = jnp.zeros((bt.p, bt.m, bt.k, bt.k), precond_dtype)
                    rhs_c = rhs_c.at[1:, 0].set(c_cpl)
                    w_full = _bts(lu, rhs_c, impl)
                    w_top = w_full[1:, 0]
                    w_bot = w_full[1:, -1]
                sp.sync((v_bot, w_top))
        if variant == "C":
            with span("factor.reduced", solver="truncated") as sp:
                eye = jnp.eye(bt.k, dtype=precond_dtype)
                rbar = eye - w_top @ v_bot
                rbar_inv = jax.vmap(lambda a: gj_inverse(a, boost_eps))(rbar)
                sp.sync(rbar_inv)
        else:
            # exact reduced system: a (P-1)-long chain of 2K x 2K blocks,
            # factored either with the same block-tridiag stack
            # (recursively, O(P) sequential sweep) or by block cyclic
            # reduction (O(log2 P) parallel levels).
            with span("factor.reduced", solver=reduced_solver) as sp:
                rd, re, rf = _reduced_interface_system(
                    v_bot, v_top, w_top, w_bot
                )
                if reduced_solver == "bcr":
                    red_bcr = _bcr_factor(rd, re, rf, boost_eps, impl)
                    sp.sync(red_bcr)
                else:
                    red_lu = _btf_chain(rd, re, rf, boost_eps, impl)
                    sp.sync(red_lu)
    elif variant in ("C", "E"):
        variant = "D"  # single partition: coupled/exact == decoupled

    return SaPPreconditioner(
        variant=variant,
        lu=lu,
        b_cpl=b_cpl,
        c_cpl=c_cpl,
        v_bot=v_bot,
        w_top=w_top,
        rbar_inv=rbar_inv,
        red_lu=red_lu,
        red_bcr=red_bcr,
        p=bt.p,
        m=bt.m,
        k=bt.k,
        impl=impl,
        reduced_solver=reduced_solver,
        fused=use_fused,
    )
