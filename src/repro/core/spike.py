"""SPIKE machinery: truncated spikes, reduced system, SaP preconditioner.

Implements paper Sec. 2.1:

  * right-spike bottom blocks   V_i^(b) = Sinv_i[M-1] @ B_i          (2.2a)
  * left-spike top blocks       W_{i+1}^(t) via the UL factorization (2.2c)
  * the truncated reduced system (2.9):
        Rbar_i               = I - W_{i+1}^(t) V_i^(b)
        Rbar_i xt_{i+1}^(t)  = g_{i+1}^(t) - W_{i+1}^(t) g_i^(b)
        xt_i^(b)             = g_i^(b) - V_i^(b) xt_{i+1}^(t)
  * the final decoupled solves (2.10).

Two preconditioner variants (paper Sec. 2.1.1):
  * SaP-D  ("decoupled"): z = D^{-1} r, one block solve.
  * SaP-C  ("coupled"):   block solve + truncated-spike correction +
                          second block solve.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .banded import BlockTridiag
from .block_lu import (
    DEFAULT_BOOST,
    BTFactors,
    btf_ref,
    btf_ul_ref,
    bts_ref,
    gj_inverse,
)


def _flip_rows(x: jax.Array) -> jax.Array:
    return x[..., ::-1, :]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("lu", "b_cpl", "c_cpl", "v_bot", "w_top", "rbar_inv"),
    meta_fields=("variant", "p", "m", "k", "impl"),
)
@dataclasses.dataclass
class SaPPreconditioner:
    """Factored SaP preconditioner (variant 'C' coupled or 'D' decoupled).

    All factor arrays may be stored in a lower precision than the Krylov
    iteration (paper Sec. 3.1 "Mixed Precision Strategy").
    """

    variant: str  # "C" | "D"
    lu: BTFactors  # factors of diag(A_1..A_P)
    b_cpl: jax.Array  # (P-1, K, K)
    c_cpl: jax.Array  # (P-1, K, K)
    v_bot: Optional[jax.Array]  # (P-1, K, K)  V_i^(b)
    w_top: Optional[jax.Array]  # (P-1, K, K)  W_{i+1}^(t)
    rbar_inv: Optional[jax.Array]  # (P-1, K, K)  inv(I - W V)
    p: int
    m: int
    k: int
    impl: str = "jnp"  # kernel dispatch: "jnp" | "interpret" | "pallas"

    def apply(self, r: jax.Array) -> jax.Array:
        """Apply M^{-1} to a (padded) flat residual of length P*M*K."""
        dtype = self.lu.sinv.dtype
        rb = r.astype(dtype).reshape(self.p, self.m, self.k, -1)
        if self.variant == "D":
            z = _bts(self.lu, rb, self.impl)
            return z.reshape(r.shape).astype(r.dtype)
        z = _apply_coupled(self, rb)
        return z.reshape(r.shape).astype(r.dtype)


def _bts(factors, b, impl):
    """Solve through the kernel dispatch layer (lazy import: no cycles)."""
    if impl == "jnp":
        return bts_ref(factors, b)
    from repro.kernels import ops as kops

    return kops.block_tridiag_solve(factors, b, impl=impl)


def _btf(d, e, f, boost_eps, impl):
    if impl == "jnp":
        return btf_ref(d, e, f, boost_eps)
    from repro.kernels import ops as kops

    return kops.block_tridiag_factor(d, e, f, boost_eps, impl=impl)


@partial(jax.jit, static_argnames=())
def _apply_coupled(pc: SaPPreconditioner, rb: jax.Array) -> jax.Array:
    # 1) g = D^{-1} r
    g = _bts(pc.lu, rb, pc.impl)  # (P, M, K, R)
    g_top = g[:, 0]  # (P, K, R)
    g_bot = g[:, -1]  # (P, K, R)

    # 2) reduced-system correction per interface i = 0..P-2   (eq. 2.9)
    rhs = g_top[1:] - pc.w_top @ g_bot[:-1]  # (P-1, K, R)
    xt_top = pc.rbar_inv @ rhs  # xt_{i+1}^(t)
    xt_bot = g_bot[:-1] - pc.v_bot @ xt_top  # xt_i^(b)

    # 3) final solves (eq. 2.10): subtract coupling contributions
    top_corr = pc.c_cpl @ xt_bot  # into partitions 1..P-1, top block
    bot_corr = pc.b_cpl @ xt_top  # into partitions 0..P-2, bottom block
    rb2 = rb
    rb2 = rb2.at[1:, 0].add(-top_corr)
    rb2 = rb2.at[:-1, -1].add(-bot_corr)
    return _bts(pc.lu, rb2, pc.impl)


def build_preconditioner(
    bt: BlockTridiag,
    variant: str = "C",
    boost_eps: float = DEFAULT_BOOST,
    precond_dtype=jnp.float32,
    impl: str = "jnp",
    spike_mode: str = "ul",
) -> SaPPreconditioner:
    """Factor the SaP preconditioner from block-tridiagonal partitions.

    spike_mode:
      * "ul"   -- paper Sec. 2.1 fast path: V^(b) from the bottom of the LU
                  factors, W^(t) from a UL factorization (top only).
      * "full" -- compute the *entire* spikes by full solves and take the
                  needed blocks.  This is the paper's third-stage-reordering
                  path (Sec. 2.2.1: per-partition reordering "renders the UL
                  factorization superfluous" and mandates whole spikes).
    """
    if variant not in ("C", "D"):
        raise ValueError(f"unknown SaP variant {variant!r}")
    if spike_mode not in ("ul", "full"):
        raise ValueError(f"unknown spike_mode {spike_mode!r}")
    d = bt.d.astype(precond_dtype)
    e = bt.e.astype(precond_dtype)
    f = bt.f.astype(precond_dtype)
    b_cpl = bt.b_cpl.astype(precond_dtype)
    c_cpl = bt.c_cpl.astype(precond_dtype)

    lu = _btf(d, e, f, boost_eps, impl)

    v_bot = w_top = rbar_inv = None
    if variant == "C" and bt.p > 1:
        if spike_mode == "ul":
            # V_i^(b) = Sinv_i[M-1] @ B_i  for i = 0..P-2
            v_bot = lu.sinv[:-1, -1] @ b_cpl
            # W_{i+1}^(t) from the UL factorization of partitions 1..P-1
            ul = btf_ul_ref(d, e, f, boost_eps)
            w_top = _flip_rows(ul.sinv[1:, -1] @ _flip_rows(c_cpl))
        else:
            # whole right spikes: A_i V_i = [0;..;B_i], keep bottom blocks
            rhs_b = jnp.zeros((bt.p, bt.m, bt.k, bt.k), precond_dtype)
            rhs_b = rhs_b.at[:-1, -1].set(b_cpl)
            v_full = _bts(lu, rhs_b, impl)
            v_bot = v_full[:-1, -1]
            # whole left spikes: A_{i+1} W_{i+1} = [C_{i+1};0;..], keep tops
            rhs_c = jnp.zeros((bt.p, bt.m, bt.k, bt.k), precond_dtype)
            rhs_c = rhs_c.at[1:, 0].set(c_cpl)
            w_full = _bts(lu, rhs_c, impl)
            w_top = w_full[1:, 0]
        eye = jnp.eye(bt.k, dtype=precond_dtype)
        rbar = eye - w_top @ v_bot
        rbar_inv = jax.vmap(lambda a: gj_inverse(a, boost_eps))(rbar)
    elif variant == "C":
        variant = "D"  # single partition: coupled == decoupled

    return SaPPreconditioner(
        variant=variant,
        lu=lu,
        b_cpl=b_cpl,
        c_cpl=c_cpl,
        v_bot=v_bot,
        w_top=w_top,
        rbar_inv=rbar_inv,
        p=bt.p,
        m=bt.m,
        k=bt.k,
        impl=impl,
    )
