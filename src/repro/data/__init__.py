from .pipeline import BinTokenDataset, DataConfig, SyntheticLM, make_source  # noqa: F401
