"""Deterministic, shard-aware data pipeline.

Two sources:

* ``SyntheticLM`` -- an infinite stream with a learnable affine-bigram
  structure (t_{i+1} = (a t_i + b) mod V with noise), so integration tests
  can assert the training loss actually decreases.
* ``BinTokenDataset`` -- memmap-backed flat token files (production path).

Determinism & elasticity: every batch is derived from (seed, step,
shard_id), never from iterator state, so a restarted or re-sharded job
resumes bit-identically -- the data-side half of fault tolerance.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1  # fraction of random tokens in the synthetic stream
    mult: int = 5
    add: int = 17


class SyntheticLM:
    """Infinite synthetic LM stream; batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig, shard_id: int = 0, n_shards: int = 1):
        assert cfg.global_batch % n_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.local_batch = cfg.global_batch // n_shards

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard_id])
        )
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((b, s), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        noise_mask = rng.random((b, s)) < cfg.noise
        noise_vals = rng.integers(0, v, size=(b, s))
        for t in range(1, s):
            nxt = (cfg.mult * toks[:, t - 1] + cfg.add) % v
            toks[:, t] = np.where(noise_mask[:, t], noise_vals[:, t], nxt)
        return {"tokens": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class BinTokenDataset:
    """Flat .bin int32 token file, memmap'd; deterministic strided batches."""

    def __init__(self, path: str | Path, cfg: DataConfig, shard_id: int = 0,
                 n_shards: int = 1):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.shard_id = shard_id
        self.n_shards = n_shards
        self.local_batch = cfg.global_batch // n_shards
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard_id])
        )
        idx = rng.integers(0, self.n_windows, size=self.local_batch)
        out = np.stack(
            [self.tokens[i * cfg.seq_len : (i + 1) * cfg.seq_len] for i in idx]
        )
        return {"tokens": out.astype(np.int32)}


def make_source(cfg: DataConfig, path: str | None = None, shard_id: int = 0,
                n_shards: int = 1):
    if path:
        return BinTokenDataset(path, cfg, shard_id, n_shards)
    return SyntheticLM(cfg, shard_id, n_shards)
