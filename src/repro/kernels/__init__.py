"""Pallas TPU kernels for SaP::TPU's compute hot-spots.

The paper hand-optimizes exactly these stages on the GPU (Sec. 3.1); here
they are TPU-native Pallas kernels:

  * ``btf``        -- block-tridiagonal factorization (the paper's banded
                      LU "window sliding", re-blocked for the MXU)
  * ``bts``        -- forward/backward block solves (preconditioner apply
                      + spike computation)
  * ``wkv_chunk``  -- chunked RWKV6 recurrence (SaP applied along the
                      sequence axis of a block-bidiagonal system)
  * ``ssd_chunk``  -- chunked Mamba-2 SSD recurrence (same, scalar decay)
  * ``flash_attn`` -- causal/windowed GQA flash attention (beyond-paper,
                      motivated by the roofline memory term)

``ops`` holds the jit'd dispatch wrappers, ``ref`` the pure-jnp oracles.
"""

from . import ops, ref  # noqa: F401
from .flash_attn import flash_attention_pallas  # noqa: F401
from .ops import (  # noqa: F401
    block_tridiag_factor,
    block_tridiag_solve,
    default_impl,
    ssd,
    wkv6,
)
