"""Pallas TPU kernels: block cyclic reduction (SaP-E reduced-chain stage).

Log-depth counterpart of the sequential chain kernels in ``btf.py`` /
``bts.py``: one even/odd elimination level of the reduced interface chain
is a *parallel* grid over the m/2 even block rows -- no sequential VMEM
carry at all, the dependency depth lives in the O(log2 M) host-level loop
over ``pallas_call``s instead of in an O(M) grid walk.  Each grid cell
streams the handful of (K, K) blocks it touches from HBM via BlockSpec
index maps (neighbor access = clamped index map; the algebra zeroes the
clamped terms at the chain ends) and does pure MXU matmuls plus one
boosted Gauss-Jordan inversion.

Four kernels implement the two public entry points (the factor/solve
kernel pair dispatched by ``repro.kernels.ops``):

  bcr_factor_pallas : _inv_odd (invert odd diagonals)  +  _reduce
                      (build lo/hi and the half-length chain), per level
  bcr_solve_pallas  : _rhs_reduce (fold odd RHS into even equations)
                      going down, _backsub (recover odd unknowns,
                      interleave) coming back up

The pure-jnp oracle is ``repro.core.cyclic_reduction``; both paths build
the identical :class:`~repro.core.cyclic_reduction.BCRFactors` pytree.
Both inherit the structural-zero pivot exemption of
:func:`repro.core.block_lu.gj_inverse`: exactly-zero block rows (identity
padding) invert to identity slots instead of boosted ``1/thr`` garbage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pallas_compat import CompilerParams

from repro.core.block_lu import DEFAULT_BOOST, gj_inverse
from repro.core.cyclic_reduction import BCRFactors, BCRLevel, pad_chain


def _inv_odd_kernel(d_ref, a_ref, *, boost_eps):
    d = d_ref[0].astype(jnp.float32)
    a_ref[0] = gj_inverse(d, boost_eps).astype(a_ref.dtype)


def _reduce_kernel(
    d_ref, e_ref, en_ref, ep_ref, f_ref, fn_ref, fp_ref, ac_ref, ap_ref,
    dn_ref, eo_ref, fo_ref, lo_ref, hi_ref,
):
    """One even row 2i of one elimination level.

    Inputs: D/E/F at 2i, E/F at 2i+1 (next) and 2i-1 (prev, clamped --
    E_{2i} = 0 at i = 0 kills the clamped terms exactly), inv(D) at odd
    2i+1 (ac) and 2i-1 (ap, clamped).  Outputs: the level-(l+1) chain
    blocks D'/E'/F' and the RHS-reduction multipliers lo/hi.
    """
    d = d_ref[0].astype(jnp.float32)
    e = e_ref[0].astype(jnp.float32)
    e_next = en_ref[0].astype(jnp.float32)
    e_prev = ep_ref[0].astype(jnp.float32)
    f = f_ref[0].astype(jnp.float32)
    f_next = fn_ref[0].astype(jnp.float32)
    f_prev = fp_ref[0].astype(jnp.float32)
    a_cur = ac_ref[0].astype(jnp.float32)
    a_prev = ap_ref[0].astype(jnp.float32)

    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    lo = dot(e, a_prev)
    hi = dot(f, a_cur)
    dn_ref[0] = (d - dot(lo, f_prev) - dot(hi, e_next)).astype(dn_ref.dtype)
    eo_ref[0] = (-dot(lo, e_prev)).astype(eo_ref.dtype)
    fo_ref[0] = (-dot(hi, f_next)).astype(fo_ref.dtype)
    lo_ref[0] = lo.astype(lo_ref.dtype)
    hi_ref[0] = hi.astype(hi_ref.dtype)


def _rhs_reduce_kernel(lo_ref, hi_ref, b_ref, bp_ref, bn_ref, out_ref):
    lo = lo_ref[0].astype(jnp.float32)
    hi = hi_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    b_prev = bp_ref[0].astype(jnp.float32)  # b_{2i-1}, clamped (lo_0 = 0)
    b_next = bn_ref[0].astype(jnp.float32)  # b_{2i+1}
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    out_ref[0] = (b - dot(lo, b_prev) - dot(hi, b_next)).astype(out_ref.dtype)


def _backsub_kernel(a_ref, e_ref, f_ref, b_ref, x_ref, xn_ref, out_ref):
    """Recover odd unknown 2i+1 and interleave: out block = [x_{2i}; x_{2i+1}]."""
    a = a_ref[0].astype(jnp.float32)
    e = e_ref[0].astype(jnp.float32)
    f = f_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)
    x_even = x_ref[0].astype(jnp.float32)
    x_next = xn_ref[0].astype(jnp.float32)  # x_{2i+2}, clamped (f_odd end = 0)
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=jnp.float32)
    x_odd = dot(a, b - dot(e, x_even) - dot(f, x_next))
    out_ref[0] = x_even.astype(out_ref.dtype)
    out_ref[1] = x_odd.astype(out_ref.dtype)


def _specs(k, last, *idx_maps):
    return [
        pl.BlockSpec((1, k, last), imap) for imap in idx_maps
    ]


_PARALLEL = CompilerParams(dimension_semantics=("parallel",))


# ---------------------------------------------------------------------------
# Lane padding (ROADMAP item: small-K blocks vs the 8x128 fp32 tile)
# ---------------------------------------------------------------------------
#
# TPU vector memory tiles fp32 as (8, 128): the second-to-last dim must be
# a multiple of 8 and the last a multiple of 128 for the compiled Pallas
# path.  The reduced-chain block size K (and the RHS width R) are usually
# far below 128, so the compiled kernels embed each (K, K) block into a
# lane-aligned (K', K') block: D picks up an identity tail (decoupled
# rows that carry the zero solution), E / F / RHS pick up zeros.  The
# algebra is exact -- inv(blkdiag(A, I)) = blkdiag(inv(A), I) and all
# cross terms against the padded rows are zero -- so the padded factors
# solve the original chain bit-for-bit up to float roundoff; the solve
# slices the padding back off.  ``lane_pad=None`` enables padding exactly
# when the kernels compile for real (interpret=False); interpret-mode
# tests can force it on to validate the padded algebra on CPU.


def _lane_round(x: int) -> int:
    """Round a block dim up to the fp32 tile: mult of 8, last-dim 128."""
    return max(-(-x // 8) * 8, -(-x // 128) * 128)


def _resolve_lane_pad(lane_pad: bool | None, interpret: bool) -> bool:
    return (not interpret) if lane_pad is None else lane_pad


def _pad_block_dim(x: jax.Array, kp: int, identity: bool) -> jax.Array:
    """(m, K, K) -> (m, K', K'): identity (D blocks) or zero (E/F) tail."""
    m, k, _ = x.shape
    if kp == k:
        return x
    out = jnp.zeros((m, kp, kp), x.dtype)
    if identity:
        idx = jnp.arange(k, kp)
        out = out.at[:, idx, idx].set(1.0)
    return out.at[:, :k, :k].set(x)


def _pad_last(x: jax.Array, rp: int) -> jax.Array:
    """(m, K, R) -> (m, K, R'): zero-pad the trailing (lane) dim."""
    if rp == x.shape[-1]:
        return x
    pad = jnp.zeros(x.shape[:-1] + (rp - x.shape[-1],), x.dtype)
    return jnp.concatenate([x, pad], axis=-1)


def _reduce_level_pallas(d, e, f, boost_eps, interpret):
    """One elimination level: (m, K, K) chain -> level factors + m/2 chain."""
    m, k, _ = d.shape
    m2 = m // 2
    sd = jax.ShapeDtypeStruct

    a_odd = pl.pallas_call(
        functools.partial(_inv_odd_kernel, boost_eps=boost_eps),
        grid=(m2,),
        in_specs=_specs(k, k, lambda i: (2 * i + 1, 0, 0)),
        out_specs=pl.BlockSpec((1, k, k), lambda i: (i, 0, 0)),
        out_shape=sd((m2, k, k), d.dtype),
        interpret=interpret,
        compiler_params=_PARALLEL,
    )(d)

    cur = lambda i: (2 * i, 0, 0)
    nxt = lambda i: (2 * i + 1, 0, 0)
    prv = lambda i: (jnp.maximum(2 * i - 1, 0), 0, 0)
    a_cur = lambda i: (i, 0, 0)
    a_prv = lambda i: (jnp.maximum(i - 1, 0), 0, 0)
    d_n, e_n, f_n, lo, hi = pl.pallas_call(
        _reduce_kernel,
        grid=(m2,),
        in_specs=_specs(
            k, k, cur, cur, nxt, prv, cur, nxt, prv, a_cur, a_prv
        ),
        out_specs=_specs(k, k, *([a_cur] * 5)),
        out_shape=[sd((m2, k, k), d.dtype)] * 5,
        interpret=interpret,
        compiler_params=_PARALLEL,
    )(d, e, e, e, f, f, f, a_odd, a_odd)
    level = BCRLevel(lo=lo, hi=hi, a_odd=a_odd, e_odd=e[1::2], f_odd=f[1::2])
    return level, (d_n, e_n, f_n)


@functools.partial(
    jax.jit, static_argnames=("boost_eps", "interpret", "lane_pad")
)
def bcr_factor_pallas(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    interpret: bool = True,
    lane_pad: bool | None = None,
) -> BCRFactors:
    """Factor one chain (M, K, K) in log2(M) kernel-level rounds.

    ``lane_pad`` embeds small-K blocks into the (8, 128) fp32 tile before
    the kernels run (see the lane-padding note above); the returned
    factors then hold K'-sized blocks, which :func:`bcr_solve_pallas`
    detects and undoes.  Default ``None`` = pad iff compiling for real.
    """
    m, k = d.shape[0], d.shape[1]
    if _resolve_lane_pad(lane_pad, interpret):
        kp = _lane_round(k)
        d = _pad_block_dim(d, kp, identity=True)
        e = _pad_block_dim(e, kp, identity=False)
        f = _pad_block_dim(f, kp, identity=False)
    d, e, f = pad_chain(d, e, f)
    levels = []
    while d.shape[0] > 1:
        level, (d, e, f) = _reduce_level_pallas(d, e, f, boost_eps, interpret)
        levels.append(level)
    root_inv = gj_inverse(d[0].astype(jnp.float32), boost_eps).astype(d.dtype)
    return BCRFactors(levels=tuple(levels), root_inv=root_inv, m=m)


@functools.partial(jax.jit, static_argnames=("interpret", "lane_pad"))
def bcr_solve_pallas(
    factors: BCRFactors, b: jax.Array, interpret: bool = True,
    lane_pad: bool | None = None,
) -> jax.Array:
    """Solve one factored chain: b (M, K, R) -> x (M, K, R).

    Factors produced with lane padding carry K'-sized blocks; the RHS is
    embedded to match (zero rows) and the solution sliced back.  The RHS
    width R is itself a lane dim and gets zero-padded to the 128 tile
    whenever lane padding is active.
    """
    m, k0, r0 = b.shape
    kp = factors.root_inv.shape[-1]  # block dim the factors were built at
    if kp != k0:
        b = jnp.concatenate(
            [b, jnp.zeros((m, kp - k0, r0), b.dtype)], axis=1
        )
    if _resolve_lane_pad(lane_pad, interpret) or kp != k0:
        b = _pad_last(b, -(-r0 // 128) * 128)
    m, k, r = b.shape
    sd = jax.ShapeDtypeStruct
    m_pad = 2 ** len(factors.levels) if factors.levels else 1
    if m_pad != m:
        b = jnp.concatenate([b, jnp.zeros((m_pad - m, k, r), b.dtype)], 0)

    cur = lambda i: (i, 0, 0)
    saved_odd = []
    for lv in factors.levels:
        m2 = b.shape[0] // 2
        saved_odd.append(b[1::2])
        b = pl.pallas_call(
            _rhs_reduce_kernel,
            grid=(m2,),
            in_specs=_specs(k, k, cur, cur)
            + _specs(
                k,
                r,
                lambda i: (2 * i, 0, 0),
                lambda i: (jnp.maximum(2 * i - 1, 0), 0, 0),
                lambda i: (2 * i + 1, 0, 0),
            ),
            out_specs=pl.BlockSpec((1, k, r), cur),
            out_shape=sd((m2, k, r), b.dtype),
            interpret=interpret,
            compiler_params=_PARALLEL,
        )(lv.lo, lv.hi, b, b, b)

    x = (factors.root_inv @ b[0])[None]
    for lv, b_odd in zip(reversed(factors.levels), reversed(saved_odd)):
        m2 = x.shape[0]
        x = pl.pallas_call(
            _backsub_kernel,
            grid=(m2,),
            in_specs=_specs(k, k, cur, cur, cur)
            + _specs(k, r, cur, cur, lambda i: (jnp.minimum(i + 1, m2 - 1), 0, 0)),
            out_specs=pl.BlockSpec((2, k, r), cur),
            out_shape=sd((2 * m2, k, r), x.dtype),
            interpret=interpret,
            compiler_params=_PARALLEL,
        )(lv.a_odd, lv.e_odd, lv.f_odd, b_odd, x, x)
    return x[:factors.m, :k0, :r0]
