"""Pallas TPU kernel: block-tridiagonal factorization (SaP T_LU stage).

TPU adaptation of the paper's dense-banded LU (Sec. 3.1).  The paper's
GPU implementation slides a (K+1)x(K+1) scalar window with one thread per
matrix entry; on TPU we instead factor the band as a block-tridiagonal
chain of (K x K) blocks so each step is an MXU matmul:

    S_0 = D_0,   L_j = E_j inv(S_{j-1}),   S_j = D_j - L_j F_{j-1}

Grid layout: ``(P, M)`` -- partitions on the (parallel) first axis, block
rows on the (sequential, innermost) second axis.  The running inverse
``inv(S_{j-1})`` lives in a VMEM scratch buffer that persists across the
sequential ``j`` steps; each grid step streams one (K, K) block of D / E /
F from HBM into VMEM via the BlockSpecs, exactly the "window of focus"
pattern of the paper mapped onto the TPU memory hierarchy.

Pivoting is replaced by pivot boosting inside the Gauss-Jordan inversion
(paper Sec. 2.2), which keeps the kernel branch-free -- the property that
made the original algorithm GPU-friendly makes it MXU/VPU-friendly here.
Structurally zero pivot rows (identity padding from shape bucketing) are
exempt from boosting and take pivot 1 instead -- see
:func:`repro.core.block_lu.gj_inverse`, shared by kernel and oracle, so
padded embeddings stay exactly blkdiag(A, I) in both paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

from repro.core.block_lu import DEFAULT_BOOST, gj_inverse


def _btf_kernel(d_ref, e_ref, f_prev_ref, sinv_ref, l_ref, carry, *, boost_eps):
    j = pl.program_id(1)

    d = d_ref[0, 0].astype(jnp.float32)

    @pl.when(j == 0)
    def _first():
        sinv = gj_inverse(d, boost_eps)
        carry[...] = sinv
        sinv_ref[0, 0] = sinv.astype(sinv_ref.dtype)
        l_ref[0, 0] = jnp.zeros_like(d).astype(l_ref.dtype)

    @pl.when(j > 0)
    def _rest():
        e = e_ref[0, 0].astype(jnp.float32)
        f_prev = f_prev_ref[0, 0].astype(jnp.float32)
        lj = jnp.dot(e, carry[...], preferred_element_type=jnp.float32)
        sj = d - jnp.dot(lj, f_prev, preferred_element_type=jnp.float32)
        sinv = gj_inverse(sj, boost_eps)
        carry[...] = sinv
        sinv_ref[0, 0] = sinv.astype(sinv_ref.dtype)
        l_ref[0, 0] = lj.astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("boost_eps", "interpret"))
def btf_pallas(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    interpret: bool = True,
):
    """Factor all partitions.  d/e/f: (P, M, K, K) -> (sinv, l) same shape."""
    p, m, k, _ = d.shape
    blk = (1, 1, k, k)
    spec_j = pl.BlockSpec(blk, lambda i, j: (i, j, 0, 0))
    spec_jm1 = pl.BlockSpec(blk, lambda i, j: (i, jnp.maximum(j - 1, 0), 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct(d.shape, d.dtype),  # sinv
        jax.ShapeDtypeStruct(d.shape, d.dtype),  # l
    ]
    return pl.pallas_call(
        functools.partial(_btf_kernel, boost_eps=boost_eps),
        grid=(p, m),
        in_specs=[spec_j, spec_j, spec_jm1],
        out_specs=[spec_j, spec_j],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((k, k), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(d, e, f)
