"""Pallas TPU kernels: block-tridiagonal solve (SaP forward/backward sweeps).

Two kernels, each on grid ``(P, M)`` with a VMEM carry:

  forward:   y_0 = b_0,          y_j = b_j - L_j y_{j-1}
  backward:  x_{M-1} = Sinv y,   x_j = Sinv_j (y_j - F_j x_{j+1})

The backward kernel runs the same ascending grid but its BlockSpec
index_map reverses the block-row axis, so the sequential VMEM carry walks
the partition bottom-up.  Multiple right-hand sides (R columns) are
handled in one pass -- the spike computation (paper Sec. 2.1) is just this
solve with R = K columns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _fwd_kernel(l_ref, b_ref, y_ref, carry):
    j = pl.program_id(1)
    b = b_ref[0, 0].astype(jnp.float32)

    @pl.when(j == 0)
    def _first():
        carry[...] = b
        y_ref[0, 0] = b.astype(y_ref.dtype)

    @pl.when(j > 0)
    def _rest():
        l = l_ref[0, 0].astype(jnp.float32)
        y = b - jnp.dot(l, carry[...], preferred_element_type=jnp.float32)
        carry[...] = y
        y_ref[0, 0] = y.astype(y_ref.dtype)


def _bwd_kernel(sinv_ref, f_ref, y_ref, x_ref, carry):
    jr = pl.program_id(1)  # 0 .. M-1, walking bottom-up via index_map
    sinv = sinv_ref[0, 0].astype(jnp.float32)
    y = y_ref[0, 0].astype(jnp.float32)

    @pl.when(jr == 0)
    def _first():
        x = jnp.dot(sinv, y, preferred_element_type=jnp.float32)
        carry[...] = x
        x_ref[0, 0] = x.astype(x_ref.dtype)

    @pl.when(jr > 0)
    def _rest():
        f = f_ref[0, 0].astype(jnp.float32)
        rhs = y - jnp.dot(f, carry[...], preferred_element_type=jnp.float32)
        x = jnp.dot(sinv, rhs, preferred_element_type=jnp.float32)
        carry[...] = x
        x_ref[0, 0] = x.astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bts_pallas(
    sinv: jax.Array,
    l: jax.Array,
    f: jax.Array,
    b: jax.Array,
    interpret: bool = True,
):
    """Solve D x = b for all partitions.

    sinv/l/f: (P, M, K, K);  b: (P, M, K, R)  ->  x: (P, M, K, R).
    """
    p, m, k, _ = sinv.shape
    r = b.shape[-1]
    blk_m = (1, 1, k, k)
    blk_v = (1, 1, k, r)
    fwd_spec_m = pl.BlockSpec(blk_m, lambda i, j: (i, j, 0, 0))
    fwd_spec_v = pl.BlockSpec(blk_v, lambda i, j: (i, j, 0, 0))

    y = pl.pallas_call(
        _fwd_kernel,
        grid=(p, m),
        in_specs=[fwd_spec_m, fwd_spec_v],
        out_specs=fwd_spec_v,
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((k, r), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(l, b)

    rev_m = pl.BlockSpec(blk_m, lambda i, j: (i, m - 1 - j, 0, 0))
    rev_v = pl.BlockSpec(blk_v, lambda i, j: (i, m - 1 - j, 0, 0))
    x = pl.pallas_call(
        _bwd_kernel,
        grid=(p, m),
        in_specs=[rev_m, rev_m, rev_v],
        out_specs=rev_v,
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((k, r), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(sinv, f, y)
    return x
