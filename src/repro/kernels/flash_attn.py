"""Pallas TPU kernel: causal/windowed GQA flash attention.

Beyond-paper optimization motivated by the roofline analysis
(EXPERIMENTS.md section Perf): the jnp chunked-attention fallback
materializes (Bq, Bk) score blocks in HBM between kernels, which makes
every attention-heavy cell memory-bound.  This kernel keeps the running
(m, l, acc) online-softmax state and the score block in VMEM; its HBM
traffic is exactly q, k, v in + o out.

Grid: ``(B*Hkv, Tq/Bq, Tk/Bk)`` with the kv axis innermost (sequential).
Causal + sliding-window masks are applied per block; blocks that are
entirely masked skip their matmuls via ``pl.when`` (the causal 2x FLOP
waste of the fallback disappears).  GQA: the G query heads of one KV head
are folded into the q-block rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q, block_k, tk, causal, window, scale):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k
    # block-level skip: entirely-future (causal) or entirely-outside-window
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 >= q_start - (window - 1)
        )

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)  # (G*Bq, D)
        k = k_ref[0].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0].astype(jnp.float32)  # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (G*Bq, Bk)

        g_bq = q.shape[0]
        g = g_bq // block_q
        # row r of s corresponds to query position q_start + (r % block_q)
        ridx = jax.lax.broadcasted_iota(jnp.int32, (g_bq, block_k), 0)
        q_pos = q_start + jnp.remainder(ridx, block_q)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (g_bq, block_k), 1
        )
        mask = k_pos < tk
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _flush():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hk, Tk, D)
    v: jax.Array,  # (B, Hk, Tk, D)
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    b, hq, tq, d = q.shape
    hk, tk = k.shape[1], k.shape[2]
    g = hq // hk
    assert tq % block_q == 0 and tk % block_k == 0
    scale = 1.0 / (d ** 0.5)

    # fold (B, Hk) into the grid; interleave the G query heads of one KV
    # head into each q-block (one block = G copies of its Bq rows)
    qg = (
        q.reshape(b, hk, g, tq, d)
        .reshape(b * hk, g, tq, d)
        .transpose(0, 2, 1, 3)  # (BHk, Tq, G, D)
        .reshape(b * hk, tq // block_q, block_q, g, d)
        .transpose(0, 1, 3, 2, 4)  # (BHk, nq, G, Bq, D)
        .reshape(b * hk, tq // block_q * g * block_q, d)
    )
    kf = k.reshape(b * hk, tk, d)
    vf = v.reshape(b * hk, tk, d)

    nq = tq // block_q
    nk = tk // block_k
    q_spec = pl.BlockSpec((1, g * block_q, d), lambda h, i, j: (h, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0))
    o = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, tk=tk,
            causal=causal, window=window, scale=scale,
        ),
        grid=(b * hk, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b * hk, tq * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(qg, kf, vf)

    # undo the interleaved layout
    o = (
        o.reshape(b * hk, nq, g, block_q, d)
        .transpose(0, 2, 1, 3, 4)  # (BHk, G, nq, Bq, D)
        .reshape(b, hk, g, tq, d)
        .reshape(b, hq, tq, d)
    )
    return o
