"""Pallas TPU megakernel: fused block-LU factor + spike extraction.

One ``pallas_call`` grid over ``(P, M)`` replaces the btf -> UL-btf ->
bts kernel *sequence* of the SaP factor stage (paper Sec. 3.1: SaP::GPU
factors each diagonal sub-block and extracts its spikes in a single
on-chip pass).  Four K x K carries live in VMEM across the sequential
``j`` axis:

  * ``c_lu`` -- the LU recurrence carry ``inv(S_{j-1})`` (as in
    ``kernels/btf.py``); ``sinv_j`` / ``l_j`` stream out as usual.
  * ``c_ul`` -- the SAME recurrence on the *reversed* chain
    (``flip_block_tridiag``), i.e. the UL factorization.  Only the carry
    is kept: no UL factors are ever materialized in HBM, which is the
    bulk of the HBM traffic the kernel sequence pays.
  * ``c_w``  -- the left-spike RHS swept forward through LU:
    ``y_0 = C_i``, ``y_j = -l_j y_{j-1}`` (rhs is zero past block 0), so
    ``w_bot = sinv_{M-1} y_{M-1}`` without a backward substitution.
  * ``c_v``  -- the right-spike RHS swept forward through UL:
    ``yr_0 = flip(B_i)``, ``yr_j = -l^{UL}_j yr_{j-1}``, so
    ``v_top = flip(sinv^{UL}_{M-1} yr_{M-1})``.

At ``j = M-1`` the four spike corner blocks (v_bot / v_top / w_top /
w_bot) are emitted into constant-index output blocks (flushed once at the
end of each partition's sweep).  The reversed-chain blocks are read
through reversed BlockSpec index maps (the ``kernels/bts.py`` backward
idiom) and flipped in VMEM, so no reversed copy of the chain exists in
HBM either.

Oracle: :func:`repro.core.block_lu.fused_factor_spike_padded_ref`, the
op-for-op scan formulation -- interpret mode matches it bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

from repro.core.block_lu import DEFAULT_BOOST, gj_inverse


def _fused_kernel(
    d_ref, e_ref, f_prev_ref, d_rev_ref, f_rev_ref, e_revp1_ref,
    bq_ref, cq_ref,
    sinv_ref, l_ref, vb_ref, vt_ref, wt_ref, wb_ref,
    c_lu, c_ul, c_w, c_v,
    *, boost_eps,
):
    j = pl.program_id(1)
    m = pl.num_programs(1)

    d = d_ref[0, 0].astype(jnp.float32)
    # reversed-chain blocks, flipped in VMEM (flip_block_tridiag values)
    d_r = d_rev_ref[0, 0].astype(jnp.float32)[::-1, ::-1]
    bq = bq_ref[0].astype(jnp.float32)
    cq = cq_ref[0].astype(jnp.float32)

    @pl.when(j == 0)
    def _first():
        sinv = gj_inverse(d, boost_eps)
        c_lu[...] = sinv
        sinv_ref[0, 0] = sinv.astype(sinv_ref.dtype)
        l_ref[0, 0] = jnp.zeros_like(d).astype(l_ref.dtype)
        c_ul[...] = gj_inverse(d_r, boost_eps)
        c_w[...] = cq
        c_v[...] = bq[::-1, :]

    @pl.when(j > 0)
    def _rest():
        e = e_ref[0, 0].astype(jnp.float32)
        f_prev = f_prev_ref[0, 0].astype(jnp.float32)
        lj = jnp.dot(e, c_lu[...], preferred_element_type=jnp.float32)
        sj = d - jnp.dot(lj, f_prev, preferred_element_type=jnp.float32)
        sinv = gj_inverse(sj, boost_eps)
        c_lu[...] = sinv
        sinv_ref[0, 0] = sinv.astype(sinv_ref.dtype)
        l_ref[0, 0] = lj.astype(l_ref.dtype)
        c_w[...] = -jnp.dot(lj, c_w[...], preferred_element_type=jnp.float32)

        e_r = f_rev_ref[0, 0].astype(jnp.float32)[::-1, ::-1]
        f_r_prev = e_revp1_ref[0, 0].astype(jnp.float32)[::-1, ::-1]
        l_ul = jnp.dot(e_r, c_ul[...], preferred_element_type=jnp.float32)
        s_ul = d_r - jnp.dot(l_ul, f_r_prev, preferred_element_type=jnp.float32)
        c_ul[...] = gj_inverse(s_ul, boost_eps)
        c_v[...] = -jnp.dot(l_ul, c_v[...], preferred_element_type=jnp.float32)

    @pl.when(j == m - 1)
    def _emit():
        sinv = c_lu[...]
        sinv_ul = c_ul[...]
        vb_ref[0] = jnp.dot(
            sinv, bq, preferred_element_type=jnp.float32
        ).astype(vb_ref.dtype)
        wb_ref[0] = jnp.dot(
            sinv, c_w[...], preferred_element_type=jnp.float32
        ).astype(wb_ref.dtype)
        wt_ref[0] = jnp.dot(
            sinv_ul, cq[::-1, :], preferred_element_type=jnp.float32
        )[::-1, :].astype(wt_ref.dtype)
        vt_ref[0] = jnp.dot(
            sinv_ul, c_v[...], preferred_element_type=jnp.float32
        )[::-1, :].astype(vt_ref.dtype)


@functools.partial(jax.jit, static_argnames=("boost_eps", "interpret"))
def fused_factor_spike_pallas(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    bq: jax.Array,
    cq: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    interpret: bool = True,
):
    """Fused factor + spike corners for all partitions.

    d/e/f: (P, M, K, K); bq/cq: (P, K, K) per-partition couplings (see
    :func:`repro.core.block_lu.pad_couplings`).  Returns
    ``(sinv, l, vb, vt, wt, wb)``: the LU factors (P, M, K, K) and the
    four spike corner blocks (P, K, K).
    """
    p, m, k, _ = d.shape
    blk = (1, 1, k, k)
    spec_j = pl.BlockSpec(blk, lambda i, j: (i, j, 0, 0))
    spec_jm1 = pl.BlockSpec(blk, lambda i, j: (i, jnp.maximum(j - 1, 0), 0, 0))
    spec_rev = pl.BlockSpec(blk, lambda i, j: (i, m - 1 - j, 0, 0))
    # f_r[j-1] = flip2(e[M-j]); clamp the unused j = 0 slot into range
    spec_revp1 = pl.BlockSpec(
        blk, lambda i, j: (i, jnp.minimum(m - j, m - 1), 0, 0)
    )
    blk_c = (1, k, k)
    spec_c = pl.BlockSpec(blk_c, lambda i, j: (i, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct(d.shape, d.dtype),  # sinv
        jax.ShapeDtypeStruct(d.shape, d.dtype),  # l
        jax.ShapeDtypeStruct((p, k, k), d.dtype),  # v_bot
        jax.ShapeDtypeStruct((p, k, k), d.dtype),  # v_top
        jax.ShapeDtypeStruct((p, k, k), d.dtype),  # w_top
        jax.ShapeDtypeStruct((p, k, k), d.dtype),  # w_bot
    ]
    return pl.pallas_call(
        functools.partial(_fused_kernel, boost_eps=boost_eps),
        grid=(p, m),
        in_specs=[
            spec_j, spec_j, spec_jm1, spec_rev, spec_rev, spec_revp1,
            spec_c, spec_c,
        ],
        out_specs=[spec_j, spec_j, spec_c, spec_c, spec_c, spec_c],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((k, k), jnp.float32),  # c_lu
            pltpu.VMEM((k, k), jnp.float32),  # c_ul
            pltpu.VMEM((k, k), jnp.float32),  # c_w
            pltpu.VMEM((k, k), jnp.float32),  # c_v
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(d, e, f, d, f, e, bq, cq)
