"""Jit'd public wrappers for the Pallas kernels with implementation dispatch.

``impl`` selects the execution path:
  * "jnp"       -- pure-jnp reference (default on CPU; identical math)
  * "interpret" -- Pallas kernel executed in interpret mode (CPU-validated)
  * "pallas"    -- compiled Pallas TPU kernel (the production path)

The default comes from the env var ``REPRO_KERNEL_IMPL`` and falls back to
"jnp" when no TPU is present, "pallas" otherwise, so the same model code
runs everywhere.

All three paths share :func:`repro.core.block_lu.gj_inverse` and with it
the structural-zero pivot exemption: exactly-zero block rows (identity
padding from shape bucketing) take pivot 1 instead of a boosted ``thr``,
so ``boost_eps`` only ever perturbs *numerically* small pivots.
"""

from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.block_lu import (
    DEFAULT_BOOST,
    BTFactors,
    FusedSpikeFactors,
    fused_factor_spike_padded_ref,
    pad_couplings,
)
from repro.core.cyclic_reduction import BCRFactors

from . import ref
from .bcr import bcr_factor_pallas, bcr_solve_pallas
from .btf import btf_pallas
from .bts import bts_pallas
from .fused_spike import fused_factor_spike_pallas
from .ssd_chunk import ssd_pallas
from .wkv_chunk import wkv6_pallas


def default_impl() -> str:
    """Kernel backend: REPRO_KERNEL_IMPL if set, else "pallas" on TPU, "jnp"."""
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    try:
        if jax.devices()[0].platform == "tpu":
            return "pallas"
    except Exception:  # pragma: no cover
        pass
    return "jnp"


def _interpret(impl: str) -> bool:
    return impl != "pallas"


# ---------------------------------------------------------------------------
# Block-tridiagonal factor / solve
# ---------------------------------------------------------------------------
#
# Both entry points are batch-aware: a 5-dim input carries a leading
# *system* axis (S, P, M, K, K) -- a fleet of independent block-tridiagonal
# systems (repro.core.batched).  Partitions are already an embarrassingly
# parallel grid axis, so the batch axis FOLDS into it: the Pallas kernels
# run one grid of S*P independent chains (a real batch grid axis, not a
# silent per-system fallback), and the jnp reference path vectorizes over
# the same folded axis.


def _fold_batch(x: jax.Array) -> jax.Array:
    """(S, P, ...) -> (S*P, ...): batch systems become extra partitions."""
    return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])


def _unfold_batch(x: jax.Array, s: int) -> jax.Array:
    return x.reshape((s, x.shape[0] // s) + x.shape[1:])


def block_tridiag_factor(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    impl: str | None = None,
) -> BTFactors:
    """Block-tridiagonal LU factor of (P, M, K, K) chains; 5-D input batches."""
    impl = impl or default_impl()
    if d.ndim == 5:  # batched (S, P, M, K, K): fold batch into the grid
        s = d.shape[0]
        fac = block_tridiag_factor(
            _fold_batch(d), _fold_batch(e), _fold_batch(f), boost_eps, impl
        )
        return BTFactors(
            sinv=_unfold_batch(fac.sinv, s),
            l=_unfold_batch(fac.l, s),
            f=_unfold_batch(fac.f, s),
        )
    if impl == "jnp":
        return ref.btf_ref(d, e, f, boost_eps)
    sinv, l = btf_pallas(d, e, f, boost_eps, interpret=_interpret(impl))
    return BTFactors(sinv=sinv, l=l, f=f)


def block_tridiag_solve(
    factors: BTFactors, b: jax.Array, impl: str | None = None
) -> jax.Array:
    """Solve the factored chains for (P, M, K, R) right-hand sides."""
    impl = impl or default_impl()
    if b.ndim == 5:  # batched (S, P, M, K, R): fold batch into the grid
        s = b.shape[0]
        folded = BTFactors(
            sinv=_fold_batch(factors.sinv),
            l=_fold_batch(factors.l),
            f=_fold_batch(factors.f),
        )
        return _unfold_batch(block_tridiag_solve(folded, _fold_batch(b), impl), s)
    if impl == "jnp":
        return ref.bts_ref(factors, b)
    return bts_pallas(
        factors.sinv, factors.l, factors.f, b, interpret=_interpret(impl)
    )


def block_tridiag_factor_chain(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    impl: str | None = None,
) -> BTFactors:
    """Factor a single block-tridiagonal chain (M, K, K).

    The recursive entry point for the SaP-E exact reduced interface system:
    the (P-1) coupled 2Kx2K interface blocks form one chain, factored by
    the same kernel as the partition factorization (grid (1, M)).

    A 4-dim input (S, M, K, K) is a *batch* of independent chains -- which
    is exactly the (P, M, K, K) partition layout, so the batch rides the
    parallel grid axis for free.
    """
    if d.ndim == 4:  # batched chains: the batch axis IS the partition axis
        return block_tridiag_factor(d, e, f, boost_eps, impl=impl)
    return block_tridiag_factor(d[None], e[None], f[None], boost_eps, impl=impl)


def block_tridiag_solve_chain(
    factors: BTFactors, b: jax.Array, impl: str | None = None
) -> jax.Array:
    """Solve one factored chain: b (M, K, R) -> x (M, K, R).

    b of 4 dims (S, M, K, R) solves a batch of factored chains (the
    batch axis rides the parallel partition grid axis).
    """
    if b.ndim == 4:
        return block_tridiag_solve(factors, b, impl=impl)
    return block_tridiag_solve(factors, b[None], impl=impl)[0]


# ---------------------------------------------------------------------------
# Fused factor + spike megakernel (one pass, four VMEM carries)
# ---------------------------------------------------------------------------


def _fused_padded(d, e, f, bq, cq, boost_eps, impl):
    if impl == "jnp":
        return fused_factor_spike_padded_ref(d, e, f, bq, cq, boost_eps)
    return fused_factor_spike_pallas(
        d, e, f, bq, cq, boost_eps, interpret=_interpret(impl)
    )


def fused_factor_spike(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    b_cpl: jax.Array,
    c_cpl: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    impl: str | None = None,
) -> FusedSpikeFactors:
    """Fused block-LU factor + spike-corner extraction in one pass.

    Replaces the btf -> UL-btf -> bts kernel *sequence* of the SaP factor
    stage: one grid over (partition, block-row) computes the LU factors
    AND all four spike corner blocks (v_bot / v_top / w_top / w_bot),
    carrying the UL recurrence and both spike right-hand sides in VMEM
    instead of materializing UL factors and whole K-column spikes in HBM
    (see :mod:`repro.kernels.fused_spike`).

    d/e/f: (P, M, K, K) partition blocks; b_cpl/c_cpl: (P-1, K, K)
    interface couplings.  A 5-dim input (S, P, M, K, K) with (S, P-1, K, K)
    couplings is a fleet of systems: the batch axis folds into the
    partition grid like :func:`block_tridiag_factor`.

    ``lu`` / ``v_bot`` / ``w_top`` are bit-identical to the sequence
    formulation; ``v_top`` / ``w_bot`` are algebraically equal (different
    rounding -- forward carries instead of whole-spike back-substitution).
    """
    impl = impl or default_impl()
    b_cpl = b_cpl.astype(d.dtype)
    c_cpl = c_cpl.astype(d.dtype)
    if d.ndim == 5:  # batched (S, P, M, K, K): fold batch into the grid
        s, p = d.shape[0], d.shape[1]
        bq, cq = pad_couplings(b_cpl, c_cpl, p)  # (S, P, K, K)
        out = _fused_padded(
            _fold_batch(d), _fold_batch(e), _fold_batch(f),
            _fold_batch(bq), _fold_batch(cq), boost_eps, impl,
        )
        sinv, l, vb, vt, wt, wb = (_unfold_batch(x, s) for x in out)
        return FusedSpikeFactors(
            lu=BTFactors(sinv=sinv, l=l, f=f),
            v_bot=vb[:, :-1], v_top=vt[:, :-1],
            w_top=wt[:, 1:], w_bot=wb[:, 1:],
        )
    p = d.shape[0]
    bq, cq = pad_couplings(b_cpl, c_cpl, p)
    sinv, l, vb, vt, wt, wb = _fused_padded(d, e, f, bq, cq, boost_eps, impl)
    return FusedSpikeFactors(
        lu=BTFactors(sinv=sinv, l=l, f=f),
        v_bot=vb[:-1], v_top=vt[:-1], w_top=wt[1:], w_bot=wb[1:],
    )


# ---------------------------------------------------------------------------
# Block cyclic reduction (log-depth chain factor / solve)
# ---------------------------------------------------------------------------


def bcr_factor(
    d: jax.Array,
    e: jax.Array,
    f: jax.Array,
    boost_eps: float = DEFAULT_BOOST,
    impl: str | None = None,
) -> BCRFactors:
    """Factor a chain (M, K, K) by even/odd elimination in log2(M) levels.

    Log-depth alternative to :func:`block_tridiag_factor_chain` for the
    SaP-E reduced interface system; both impls build the identical
    :class:`~repro.core.cyclic_reduction.BCRFactors` pytree.
    """
    impl = impl or default_impl()
    if impl == "jnp":
        from repro.core import cyclic_reduction as cr

        return cr.bcr_factor(d, e, f, boost_eps)
    return bcr_factor_pallas(d, e, f, boost_eps, interpret=_interpret(impl))


def bcr_solve(
    factors: BCRFactors, b: jax.Array, impl: str | None = None
) -> jax.Array:
    """Solve one BCR-factored chain: b (M, K, R) -> x (M, K, R)."""
    impl = impl or default_impl()
    if impl == "jnp":
        from repro.core import cyclic_reduction as cr

        return cr.bcr_solve(factors, b)
    return bcr_solve_pallas(factors, b, interpret=_interpret(impl))


# ---------------------------------------------------------------------------
# Analytic FLOP models (the cost observatory's sanity anchors)
# ---------------------------------------------------------------------------
#
# Leading-order algebraic flop counts of the solver kernels above, used by
# repro.obs.cost tests to keep the HLO-derived counters honest: the HLO
# walk counts every lowered elementwise op (selects, boosts, masks), so it
# lands above these, but only by a bounded constant factor -- a blown-up
# ratio means the analyzer (or the kernel) regressed.


def gj_inverse_flops(k: int) -> float:
    """Gauss-Jordan inverse of one KxK block: ~2 K^3 multiply-adds."""
    return 2.0 * k**3


def btf_flops(p: int, m: int, k: int) -> float:
    """Block-tridiag factor of P chains of M KxK blocks.

    Per interior block: one Schur-pivot inverse (2 K^3), the elimination
    product ``l = e @ sinv`` (2 K^3), and the Schur update ``d - l @ f``
    (2 K^3 + K^2).
    """
    return float(p) * m * (gj_inverse_flops(k) + 4.0 * k**3 + k * k)


def bts_flops(p: int, m: int, k: int, r: int = 1) -> float:
    """Block-tridiag solve: forward + backward sweeps, three K x K block
    mat-vecs (2 K^2 R each) per block per sweep pair."""
    return float(p) * m * 6.0 * k * k * r


def fused_factor_spike_flops(p: int, m: int, k: int) -> float:
    """Fused factor+spike megakernel: the LU recurrence twice (forward and
    reversed chains, ~6 K^3 + K^2 per block each), two K x K RHS carries
    (2 K^3 per block each), plus four corner products (2 K^3 each) per
    partition.  Compare ~2x the flops of :func:`btf_flops` alone -- but
    the kernel *sequence* it replaces pays the UL factor writeback and two
    whole-spike bts solves in HBM traffic, which is what the fused pass
    eliminates (see ``solver_stage_costs``)."""
    return 2.0 * btf_flops(p, m, k) + float(p) * m * 4.0 * k**3 + float(p) * 8.0 * k**3


def bcr_flops(m: int, k: int) -> float:
    """Cyclic reduction over a chain of M KxK blocks: ~M eliminated nodes
    across the log2(M) levels, each paying one inverse (2 K^3) and four
    update products (2 K^3 each)."""
    return float(m) * 10.0 * k**3


# ---------------------------------------------------------------------------
# Sequence-mixing recurrences (flattened over batch x heads)
# ---------------------------------------------------------------------------


def wkv6(
    r: jax.Array,  # (B, H, T, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # (H, D)
    state: jax.Array,  # (B, H, D, D)
    chunk: int = 64,
    impl: str | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6 recurrence; returns (output, final state)."""
    impl = impl or default_impl()
    if impl == "jnp":
        return ref.wkv6_chunked_ref(r, k, v, logw, u, state, chunk)
    bsz, h, t, d = r.shape
    flat = lambda x: x.reshape(bsz * h, *x.shape[2:])
    u_full = jnp.broadcast_to(u, (bsz,) + u.shape).reshape(bsz * h, d)
    o, s_out = wkv6_pallas(
        flat(r), flat(k), flat(v), flat(logw), u_full,
        state.reshape(bsz * h, d, d), chunk=chunk, interpret=_interpret(impl),
    )
    return o.reshape(bsz, h, t, d), s_out.reshape(bsz, h, d, d)


def ssd(
    x: jax.Array,  # (B, H, T, P)
    b: jax.Array,  # (B, H, T, N)
    c: jax.Array,  # (B, H, T, N)
    loga: jax.Array,  # (B, H, T)
    state: jax.Array,  # (B, H, N, P)
    chunk: int = 64,
    impl: str | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (state-space dual) scan; returns (output, final state)."""
    impl = impl or default_impl()
    if impl == "jnp":
        return ref.ssd_chunked_ref(x, b, c, loga, state, chunk)
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    flat = lambda a: a.reshape(bsz * h, *a.shape[2:])
    y, s_out = ssd_pallas(
        flat(x), flat(b), flat(c), flat(loga),
        state.reshape(bsz * h, n, p), chunk=chunk, interpret=_interpret(impl),
    )
    return y.reshape(bsz, h, t, p), s_out.reshape(bsz, h, n, p)
