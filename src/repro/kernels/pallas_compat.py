"""Version-compat shims for the Pallas TPU API surface.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` across jax releases; resolve whichever this jax has so
the kernels build against both.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
