"""Pure-jnp oracles for every Pallas kernel in this package.

The block-tridiagonal factor/solve oracles are the production reference
implementations from ``repro.core.block_lu`` (re-exported so kernel tests
have a single import point).  The sequence-mixing oracles (WKV6 / SSD)
are written as *naive sequential scans* -- the most obviously-correct
formulation -- which the chunked Pallas kernels must match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_lu import (  # noqa: F401  (re-exports)
    BTFactors,
    FusedSpikeFactors,
    btf_ref,
    btf_ul_ref,
    bts_ref,
    fused_factor_spike_padded_ref,
    fused_factor_spike_ref,
    gj_inverse,
)


# ---------------------------------------------------------------------------
# RWKV6 WKV recurrence (matrix-valued state, per-channel data-dependent decay)
# ---------------------------------------------------------------------------


def wkv6_ref(
    r: jax.Array,  # (B, H, T, D) receptance
    k: jax.Array,  # (B, H, T, D) key
    v: jax.Array,  # (B, H, T, D) value
    logw: jax.Array,  # (B, H, T, D) log decay  (<= 0)
    u: jax.Array,  # (H, D) current-token bonus
    state: jax.Array,  # (B, H, D, D) initial state  [k-dim x v-dim]
):
    """Sequential WKV6:  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
    o_t = r_t^T (S_{t-1} + diag(u * k_t)?? ...) -- precisely:
        o_t = r_t @ (S_{t-1} + (u * k_t) v_t^T)   per head.
    Returns (o, state_out), o: (B, H, T, D)."""

    def per_head(r, k, v, logw, u, s0):
        def step(s, inp):
            rt, kt, vt, lwt = inp
            o = rt @ s + (rt * u * kt).sum() * vt
            s = jnp.exp(lwt)[:, None] * s + kt[:, None] * vt[None, :]
            return s, o

        s_out, o = jax.lax.scan(step, s0, (r, k, v, logw))
        return o, s_out

    f = jax.vmap(jax.vmap(per_head))  # over B, H
    u_b = jnp.broadcast_to(u, (r.shape[0],) + u.shape)
    return f(r, k, v, logw, u_b, state)


# ---------------------------------------------------------------------------
# Mamba-2 SSD recurrence (scalar per-head decay, outer-product state)
# ---------------------------------------------------------------------------


def ssd_ref(
    x: jax.Array,  # (B, H, T, P) inputs (already dt-scaled)
    b: jax.Array,  # (B, H, T, N) input projection (dt-scaled B_t)
    c: jax.Array,  # (B, H, T, N) output projection
    loga: jax.Array,  # (B, H, T)   log decay (<= 0), already dt * A
    state: jax.Array,  # (B, H, N, P) initial state
):
    """Sequential SSD:  h_t = exp(a_t) h_{t-1} + b_t x_t^T,  y_t = c_t @ h_t.
    Returns (y, state_out), y: (B, H, T, P)."""

    def per_head(x, b, c, loga, s0):
        def step(s, inp):
            xt, bt, ct, lat = inp
            s = jnp.exp(lat) * s + bt[:, None] * xt[None, :]
            y = ct @ s
            return s, y

        s_out, y = jax.lax.scan(step, s0, (x, b, c, loga))
        return y, s_out

    f = jax.vmap(jax.vmap(per_head))
    return f(x, b, c, loga, state)


# ---------------------------------------------------------------------------
# Chunked (parallel-form) references: the SaP-scan formulation
# ---------------------------------------------------------------------------


def wkv6_chunked_ref(r, k, v, logw, u, state, chunk: int):
    """Chunked WKV6 in plain jnp (the algorithm the kernel implements).

    This is the paper's split-and-parallelize pattern applied to the
    block-*bidiagonal* system defined by the recurrence: chunk-local solves
    (intra-chunk term), plus spike/carry propagation (inter-chunk term).
    All exponentials have non-positive arguments -> no overflow.
    """
    bsz, h, t, d = r.shape
    nc = t // chunk

    def per_head(r, k, v, logw, u, s0):
        rc = r.reshape(nc, chunk, d)
        kc = k.reshape(nc, chunk, d)
        vc = v.reshape(nc, chunk, d)
        lc = logw.reshape(nc, chunk, d)

        def chunk_step(s, inp):
            rj, kj, vj, lj = inp
            lcum = jnp.cumsum(lj, axis=0)  # inclusive (C, D)
            lprev = jnp.concatenate([jnp.zeros((1, d), lj.dtype), lcum[:-1]], 0)
            # inter-chunk: o_t += (r_t * exp(Lprev_t)) @ S_in
            o_inter = (rj * jnp.exp(lprev)) @ s
            # intra-chunk: G[t, s<t] = sum_d r[t] k[s] exp(Lprev[t] - Lcum[s])
            diff = lprev[:, None, :] - lcum[None, :, :]  # (C, C, D)
            mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
            g = jnp.einsum("td,sd,tsd->ts", rj, kj, jnp.exp(diff)) * mask
            diag = (rj * u[None, :] * kj).sum(-1)  # current-token bonus
            o_intra = g @ vj + diag[:, None] * vj
            # carry: S_out = diag(exp(Lcum_last)) S + (k*exp(Llast-Lcum))^T v
            llast = lcum[-1]
            s_new = jnp.exp(llast)[:, None] * s + (
                (kj * jnp.exp(llast[None, :] - lcum)).T @ vj
            )
            return s_new, o_inter + o_intra

        s_out, oc = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lc))
        return oc.reshape(t, d), s_out

    f = jax.vmap(jax.vmap(per_head))
    u_b = jnp.broadcast_to(u, (bsz,) + u.shape)
    return f(r, k, v, logw, u_b, state)


def ssd_chunked_ref(x, b, c, loga, state, chunk: int):
    """Chunked SSD in plain jnp (the algorithm the kernel implements)."""
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    nc = t // chunk

    def per_head(x, b, c, loga, s0):
        xc = x.reshape(nc, chunk, p)
        bc = b.reshape(nc, chunk, n)
        cc = c.reshape(nc, chunk, n)
        lc = loga.reshape(nc, chunk)

        def chunk_step(s, inp):
            xj, bj, cj, lj = inp
            lcum = jnp.cumsum(lj)  # inclusive (C,)
            # inter: y_t += exp(Lcum_t) c_t @ S_in
            y_inter = jnp.exp(lcum)[:, None] * (cj @ s)
            # intra: G[t,s<=t] = (c_t . b_s) exp(Lcum_t - Lcum_s)
            diff = lcum[:, None] - lcum[None, :]
            mask = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
            g = (cj @ bj.T) * jnp.exp(jnp.where(mask, diff, -jnp.inf))
            y_intra = g @ xj
            llast = lcum[-1]
            s_new = jnp.exp(llast) * s + (bj * jnp.exp(llast - lcum)[:, None]).T @ xj
            return s_new, y_inter + y_intra

        s_out, yc = jax.lax.scan(chunk_step, s0, (xc, bc, cc, lc))
        return yc.reshape(t, p), s_out

    f = jax.vmap(jax.vmap(per_head))
    return f(x, b, c, loga, state)
