"""Pallas TPU kernel: chunked Mamba-2 SSD recurrence.

Same split-and-parallelize structure as the WKV kernel, but Mamba-2's
decay is a *scalar per head per step*, so the intra-chunk term factors
into pure matmuls -- this kernel is MXU-bound:

    G = (C B^T) * e^{Lcum_t - Lcum_s}   masked s <= t      (C, C)
    y = e^{Lcum} * (C @ S_in) + G @ X                      (C, P)
    S_out = e^{Llast} S_in + (B * e^{Llast - Lcum})^T X    (N, P)

Grid ``(B*H, T/C)``, chunk axis sequential, state carried in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _ssd_kernel(x_ref, b_ref, c_ref, la_ref, s0_ref, y_ref, sout_ref, s, *, chunk):
    nc = pl.program_id(1)
    c = chunk

    @pl.when(nc == 0)
    def _init():
        s[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (C, P)
    bmat = b_ref[0].astype(jnp.float32)  # (C, N)
    cmat = c_ref[0].astype(jnp.float32)  # (C, N)
    la = la_ref[0].astype(jnp.float32)  # (C,)

    lcum = jnp.cumsum(la)  # (C,)
    y_inter = jnp.exp(lcum)[:, None] * jnp.dot(
        cmat, s[...], preferred_element_type=jnp.float32
    )

    diff = lcum[:, None] - lcum[None, :]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    decay = jnp.where(ti >= si, jnp.exp(diff), 0.0)
    g = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32) * decay
    y = y_inter + jnp.dot(g, x, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    llast = lcum[-1]
    bd = bmat * jnp.exp(llast - lcum)[:, None]
    s[...] = jnp.exp(llast) * s[...] + jnp.dot(
        bd.T, x, preferred_element_type=jnp.float32
    )

    @pl.when(nc == pl.num_programs(1) - 1)
    def _flush():
        sout_ref[0] = s[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x: jax.Array,  # (BH, T, P)
    b: jax.Array,  # (BH, T, N)
    c: jax.Array,  # (BH, T, N)
    loga: jax.Array,  # (BH, T)
    state: jax.Array,  # (BH, N, P)
    chunk: int = 64,
    interpret: bool = True,
):
    bh, t, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, f"T={t} not divisible by chunk={chunk}"
    ncs = t // chunk
    seq_p = pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0))
    seq_n = pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0))
    seq_s = pl.BlockSpec((1, chunk), lambda i, j: (i, j))
    st = pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0))
    y, s_out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bh, ncs),
        in_specs=[seq_p, seq_n, seq_n, seq_s, st],
        out_specs=[seq_p, st],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(x, b, c, loga, state)
    return y, s_out
