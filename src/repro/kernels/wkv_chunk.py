"""Pallas TPU kernel: chunked RWKV6 WKV recurrence (the "SaP-scan").

The WKV recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T  is the solve of a
block lower-*bidiagonal* linear system in the states S_t.  Applying the
paper's split-and-parallelize idea along the *sequence* axis gives the
chunked algorithm implemented here: each chunk is a local solve (the
intra-chunk term), and the inter-chunk coupling -- the paper's spike /
reduced system, which for a lower-triangular system collapses to a carry
chain -- flows through a VMEM scratch state.

Grid: ``(B*H, T/C)`` with the chunk axis sequential.  Per chunk:

    Lcum  = cumsum(log w)                       (C, D), <= 0
    o_t   = (r_t * e^{Lprev_t}) @ S_in                        [inter]
          + sum_{s<t} (sum_d r k e^{Lprev_t - Lcum_s}) v_s    [intra]
          + (r_t . u k_t) v_t                                 [bonus]
    S_out = diag(e^{Llast}) S_in + (k * e^{Llast - Lcum})^T v

Every exponent is non-positive, so the kernel is overflow-free by
construction (no max-subtraction pass needed).  The (C, C, D) decay tensor
is materialized in VMEM -- for C = D = 64 that is 1 MiB in f32, well within
a core's VMEM; this is the price of RWKV6's *per-channel* decay and the
reason the intra term is VPU- rather than MXU-bound (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sout_ref, s, *, chunk):
    nc = pl.program_id(1)
    c = chunk
    d = r_ref.shape[-1]

    @pl.when(nc == 0)
    def _init():
        s[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (D,)

    lcum = jnp.cumsum(lw, axis=0)  # (C, D) inclusive
    lprev = jnp.concatenate([jnp.zeros((1, d), jnp.float32), lcum[:-1]], axis=0)

    # inter-chunk term (MXU): (C, D) @ (D, D)
    o_inter = jnp.dot(r * jnp.exp(lprev), s[...], preferred_element_type=jnp.float32)

    # intra-chunk term (VPU): per-channel decay prevents a pure matmul form
    diff = lprev[:, None, :] - lcum[None, :, :]  # (C, C, D), <= 0 for s < t
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = (ti > si).astype(jnp.float32)
    g = jnp.einsum("td,sd,tsd->ts", r, k, jnp.exp(diff)) * mask
    bonus = jnp.sum(r * u[None, :] * k, axis=-1)  # (C,)
    o = o_inter + jnp.dot(g, v, preferred_element_type=jnp.float32) + bonus[:, None] * v
    o_ref[0] = o.astype(o_ref.dtype)

    # carry update (MXU): S_out = diag(e^Llast) S + (k*e^{Llast-Lcum})^T v
    llast = lcum[-1]  # (D,)
    kd = k * jnp.exp(llast[None, :] - lcum)
    s[...] = jnp.exp(llast)[:, None] * s[...] + jnp.dot(
        kd.T, v, preferred_element_type=jnp.float32
    )

    @pl.when(nc == pl.num_programs(1) - 1)
    def _flush():
        sout_ref[0] = s[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(
    r: jax.Array,  # (BH, T, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # (BH, D)
    state: jax.Array,  # (BH, D, D)
    chunk: int = 64,
    interpret: bool = True,
):
    bh, t, d = r.shape
    assert t % chunk == 0, f"T={t} not divisible by chunk={chunk}"
    ncs = t // chunk
    seq = pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0))
    per_bh_vec = pl.BlockSpec((1, d), lambda i, j: (i, 0))
    per_bh_mat = pl.BlockSpec((1, d, d), lambda i, j: (i, 0, 0))
    o, s_out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(bh, ncs),
        in_specs=[seq, seq, seq, seq, per_bh_vec, per_bh_mat],
        out_specs=[seq, per_bh_mat],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), r.dtype),
            jax.ShapeDtypeStruct((bh, d, d), state.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(r, k, v, logw, u, state)
    return o, s_out
