"""Micro-benchmark calibration of the roofline hardware constants.

The tpu/gpu entries of :data:`repro.launch.roofline.BACKEND_SPECS` are
datasheet numbers, but no datasheet describes "whatever CPU the CI runner
gives us" -- the cpu entry was a placeholder order of magnitude until it
was measured.  This module measures the two roofline ceilings directly:

  * ``measure_gemm_flops`` -- peak sustained f32 FLOP/s from a jitted
    square matmul (the same XLA:CPU code path the solver's block-matmul
    kernels lower to), median over repeats.
  * ``measure_stream_bw``  -- sustained memory bandwidth from a jitted
    out-of-cache array copy, counted STREAM-style (read + write bytes).

Run it on the machine of interest::

    python -m repro.launch.calibrate

which prints the measured ceilings plus ready-to-paste environment
overrides (``REPRO_PEAK_FLOPS`` / ``REPRO_HBM_BW``, consumed by
:func:`repro.obs.cost.hardware_spec`).  Setting ``REPRO_CALIBRATE=1``
makes ``hardware_spec`` run this calibration itself, once per process,
instead of using the static table.

The committed cpu entry in ``BACKEND_SPECS`` was produced by this module;
see the provenance note there.  Calibration is intentionally cheap
(~a second) -- it measures the *ceiling* terms only, not the solver.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .roofline import HardwareSpec


def _median_seconds(fn, out, repeats: int) -> float:
    """Median wall-clock of ``fn`` (device work blocked on) over repeats."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*out))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def measure_gemm_flops(n: int = 1024, repeats: int = 5) -> float:
    """Sustained f32 FLOP/s of a jitted (n, n) @ (n, n) matmul."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n), jnp.float32)
    b = jax.random.normal(key, (n, n), jnp.float32)

    @jax.jit
    def gemm(a, b):
        return a @ b

    jax.block_until_ready(gemm(a, b))  # compile outside the timing loop
    sec = _median_seconds(gemm, (a, b), repeats)
    return 2.0 * n**3 / sec


def measure_stream_bw(nbytes: int = 1 << 28, repeats: int = 5) -> float:
    """Sustained memory bandwidth (bytes/s) of a jitted array traversal.

    The copy reads and writes ``nbytes`` (STREAM "scale" convention:
    2 x the array size per pass); the array is sized far past L2/L3 so
    the measurement is the memory system, not the caches.
    """
    n = nbytes // 4
    x = jnp.zeros((n,), jnp.float32)

    @jax.jit
    def scale(x):
        return x * 1.0001

    jax.block_until_ready(scale(x))
    sec = _median_seconds(scale, (x,), repeats)
    return 2.0 * nbytes / sec


def calibrate(gemm_n: int = 1024, stream_bytes: int = 1 << 28,
              repeats: int = 5) -> HardwareSpec:
    """Measure both ceilings and return them as a :class:`HardwareSpec`."""
    backend = jax.default_backend()
    return HardwareSpec(
        name=f"{backend}-calibrated",
        peak_flops=measure_gemm_flops(gemm_n, repeats),
        hbm_bw=measure_stream_bw(stream_bytes, repeats),
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--gemm-n", type=int, default=1024,
                    help="square matmul size (default 1024)")
    ap.add_argument("--stream-mib", type=int, default=256,
                    help="stream array size in MiB (default 256)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    spec = calibrate(args.gemm_n, args.stream_mib << 20, args.repeats)
    print(f"backend        : {jax.default_backend()}")
    print(f"peak_flops     : {spec.peak_flops:.4e} flop/s "
          f"({spec.peak_flops / 1e9:.1f} GFLOP/s f32 gemm)")
    print(f"hbm_bw         : {spec.hbm_bw:.4e} bytes/s "
          f"({spec.hbm_bw / 1e9:.1f} GB/s stream)")
    print("# env overrides for repro.obs.cost.hardware_spec:")
    print(f"export REPRO_PEAK_FLOPS={spec.peak_flops:.4e}")
    print(f"export REPRO_HBM_BW={spec.hbm_bw:.4e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
