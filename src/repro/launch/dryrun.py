import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run driver.

Lowers + compiles every (arch x input-shape x mesh) cell on placeholder
host devices and extracts memory analysis, cost analysis and the
collective schedule for the roofline report.  THE XLA_FLAGS LINE ABOVE
MUST STAY FIRST: jax locks the device count at first initialization.

Usage:
  python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --arch sap-solver --shape dense_200k --multi-pod
  python -m repro.launch.dryrun --list
Options: --multi-pod, --out out.json, --zero1, --remat {none,full,dots},
         --save-hlo hlo.txt, --variant {C,D,E} (solver; E = exact reduced
         chain via distributed cyclic reduction).
"""

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCHS, get_config
from repro.configs.sap_solver import SOLVER_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, model_flops
from repro.models import SHAPES, get_family, supports_shape

OPT_CFG = optim.AdamWConfig()


def _fsdp_pspecs(pspecs, param_shapes, mesh):
    """FSDP/ZeRO-3: extend every 'model'-sharded weight dimension to
    ('model','data') where divisible -- pjit all-gathers weights at use and
    reduce-scatters their gradients."""
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)

    def one(spec, p):
        if data <= 1 or p.ndim < 2:
            return spec
        entries = list(spec) + [None] * (p.ndim - len(spec))
        for i, e in enumerate(entries):
            if e == "model" and p.shape[i] % (model * data) == 0:
                entries[i] = ("model", "data")
                return P(*entries)
        return spec

    return jax.tree.map(one, pspecs, param_shapes,
                        is_leaf=lambda x: isinstance(x, P))


def _shardings(mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    out = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, key, None)
        if v is not None:
            out[key] = int(v)
    out["total_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def _cost_dict(compiled):
    try:
        cost = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool, args):
    cfg = get_config(arch)
    if args.remat:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    if args.ssm_chunk:
        cfg = dataclasses.replace(cfg, ssm_chunk=args.ssm_chunk)
    if args.attn_block_k:
        cfg = dataclasses.replace(cfg, attn_block_k=args.attn_block_k)
    if args.scan_dtype:
        cfg = dataclasses.replace(cfg, scan_dtype=args.scan_dtype)
    shape = SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": "unsupported (full attention @ 500k)"}
    fam = get_family(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)

    param_shapes = jax.eval_shape(lambda: fam.init(cfg, jax.random.PRNGKey(0)))
    if args.master_weights:
        # bf16 distributed params; f32 master lives (sharded) in opt state
        param_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), param_shapes
        )
    pspecs = fam.param_pspecs(cfg, mesh)
    if args.fsdp:
        pspecs = _fsdp_pspecs(pspecs, param_shapes, mesh)
    param_sh = _shardings(mesh, pspecs)
    in_specs = fam.input_specs(cfg, shape)
    bspecs = fam.batch_pspecs(cfg, shape, mesh)
    batch_sh = _shardings(mesh, bspecs)

    with mesh:
        if shape.kind == "train":
            opt_cfg = dataclasses.replace(
                OPT_CFG, master_weights=args.master_weights
            )
            opt_shapes = jax.eval_shape(
                lambda p: optim.init(p, master_weights=args.master_weights),
                param_shapes,
            )
            opt_pspecs = optim.opt_state_pspecs(
                pspecs, param_shapes, mesh, zero1=args.zero1,
                master_weights=args.master_weights,
            )
            opt_sh = _shardings(mesh, opt_pspecs)
            nmicro = args.microbatches

            def train_step(params, opt_state, batch):
                def loss_fn(p, mb):
                    l, _ = fam.loss(cfg, p, mb)
                    return l

                if nmicro == 1:
                    l, grads = jax.value_and_grad(loss_fn)(params, batch)
                else:
                    def micro(carry, mb):
                        acc, lacc = carry
                        l, g = jax.value_and_grad(loss_fn)(params, mb)
                        return (jax.tree.map(jnp.add, acc, g), lacc + l), None

                    zeros = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params
                    )
                    mbs = jax.tree.map(
                        lambda x: x.reshape(
                            nmicro, x.shape[0] // nmicro, *x.shape[1:]
                        ),
                        batch,
                    )
                    (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
                    grads = jax.tree.map(lambda g: g / nmicro, grads)
                    l = lsum / nmicro
                params, opt_state, _ = optim.apply_updates(
                    opt_cfg, params, grads, opt_state
                )
                return params, opt_state, l

            jitted = jax.jit(
                train_step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(param_shapes, opt_shapes, in_specs)
        elif shape.kind == "prefill":

            def prefill_step(params, batch):
                if cfg.family == "encdec":
                    logits, _ = fam.forward(cfg, params, batch)
                elif cfg.family in ("rwkv", "hybrid"):
                    logits, _ = fam.forward(cfg, params, batch["tokens"])
                else:
                    logits, _ = fam.forward(
                        cfg, params, batch["tokens"], batch.get("patches")
                    )
                return logits

            jitted = jax.jit(
                prefill_step, in_shardings=(param_sh, batch_sh), out_shardings=None
            )
            lowered = jitted.lower(param_shapes, in_specs)
        else:  # decode

            def serve_step(params, cache, tokens):
                return fam.decode_step(cfg, params, cache, tokens)

            jitted = jax.jit(
                serve_step,
                in_shardings=(param_sh, batch_sh["cache"], batch_sh["tokens"]),
                out_shardings=(None, batch_sh["cache"]),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(param_shapes, in_specs["cache"], in_specs["tokens"])

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    hlo = compiled.as_text()
    cost = _cost_dict(compiled)
    roof = analyze(cost, hlo, chips, model_flops(cfg, shape))
    row = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": _mem_dict(compiled),
        "cost": cost,
        "roofline": roof.to_dict(),
        "params": int(sum(
            int(jnp.prod(jnp.asarray(p.shape)))
            for p in jax.tree.leaves(param_shapes)
        )),
        "zero1": args.zero1,
        "remat": cfg.remat,
        "microbatches": args.microbatches,
        "master_weights": args.master_weights,
        "fsdp": args.fsdp,
        "ssm_chunk": cfg.ssm_chunk,
    }
    if args.save_hlo:
        with open(args.save_hlo, "w") as f:
            f.write(hlo)
    return row


# ---------------------------------------------------------------------------
# Solver cells (the paper's own workload)
# ---------------------------------------------------------------------------


def lower_solver_cell(shape_name: str, multi_pod: bool, args):
    from repro.core.distributed import build_dist_sap, solve_step_fn

    sshape = SOLVER_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    variant = args.variant
    pdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[args.precond_dtype]
    dsap = build_dist_sap(mesh, sshape.n, sshape.k, variant=variant,
                          p_per_device=args.p_per_device, precond_dtype=pdt)
    k, m = dsap.k, dsap.m
    p_total = chips * args.p_per_device
    n_pad = dsap.n_pad
    axes = tuple(mesh.axis_names)

    sd = jax.ShapeDtypeStruct
    f32 = jnp.float32
    ins = (
        sd((n_pad, 2 * k + 1), f32),  # band
        sd((n_pad,), f32),  # b
        sd((p_total, m, k, k), pdt),  # d
        sd((p_total, m, k, k), pdt),  # e
        sd((p_total, m, k, k), pdt),  # f
        sd((p_total, k, k), pdt),  # b_next
        sd((p_total, k, k), pdt),  # c_prev
    )
    shardings = (
        NamedSharding(mesh, P(axes, None)),
        NamedSharding(mesh, P(axes)),
        NamedSharding(mesh, P(axes, None, None, None)),
        NamedSharding(mesh, P(axes, None, None, None)),
        NamedSharding(mesh, P(axes, None, None, None)),
        NamedSharding(mesh, P(axes, None, None)),
        NamedSharding(mesh, P(axes, None, None)),
    )
    step = solve_step_fn(dsap, tol=1e-8, maxiter=100)
    with mesh:
        jitted = jax.jit(step, in_shardings=shardings, out_shardings=None)
        lowered = jitted.lower(*ins)
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    hlo = compiled.as_text()
    cost = _cost_dict(compiled)
    # useful flops: block factorization + one preconditioned iteration
    factor_flops = p_total * m * 8 * k**3
    iter_flops = 4 * (2 * n_pad * (2 * k + 1) + p_total * m * 8 * k * k)
    roof = analyze(cost, hlo, chips, float(factor_flops + iter_flops))
    row = {
        "arch": "sap-solver",
        "shape": shape_name,
        "kind": f"solve-{variant}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": _mem_dict(compiled),
        "cost": cost,
        "roofline": roof.to_dict(),
        "n": sshape.n,
        "k": k,
        "p_total": p_total,
        "variant": variant,
        "p_per_device": args.p_per_device,
        "precond_dtype": args.precond_dtype,
    }
    if args.save_hlo:
        with open(args.save_hlo, "w") as f:
            f.write(hlo)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "none", "full", "dots"])
    ap.add_argument("--variant", default="C", choices=["C", "D", "E"])
    ap.add_argument("--p-per-device", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--attn-block-k", type=int, default=None)
    ap.add_argument("--scan-dtype", default=None)
    ap.add_argument("--master-weights", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--precond-dtype", default="float32")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a in ARCHS:
            cfg = get_config(a)
            for s in SHAPES.values():
                mark = "" if supports_shape(cfg, s) else " (skip)"
                print(f"{a} x {s.name}{mark}")
        for s in SOLVER_SHAPES:
            print(f"sap-solver x {s}")
        return

    if args.arch == "sap-solver":
        row = lower_solver_cell(args.shape, args.multi_pod, args)
    else:
        row = lower_lm_cell(args.arch, args.shape, args.multi_pod, args)

    js = json.dumps(row, indent=2, default=str)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    print(js)
    if "memory" in row:
        mem = row["memory"].get("total_per_device", 0)
        print(
            f"\n== {row['arch']} x {row['shape']} on {row['mesh']}: "
            f"{mem/2**30:.2f} GiB/device, compile {row['compile_s']}s ==",
            file=sys.stderr,
        )


if __name__ == "__main__":
    main()
