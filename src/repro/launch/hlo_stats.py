"""Loop-aware FLOP / HBM-traffic / collective analysis of HLO text.

Why not ``compiled.cost_analysis()``?  Two measured deficiencies (see
EXPERIMENTS.md "methodology"):

  1. while-loop bodies are counted ONCE, not trip_count times -- a model
     with ``lax.scan`` over 24..56 layers under-counts by that factor;
  2. "bytes accessed" sums every operand of every instruction pre-fusion,
     over-counting HBM traffic for anything XLA fuses, and counts whole
     arrays for slice/update ops that touch only a sliver.

This module re-derives the three roofline inputs from the
post-optimization HLO text with a computation-graph walk:

  * multipliers: ENTRY = 1; while bodies x known_trip_count (from XLA's
    backend_config, falling back to the largest constant in the loop
    condition); calls/fusions/branches inherit the caller's multiplier.
  * flops: dots = 2 x numel(result) x prod(lhs contracting dims);
    elementwise/reduce = numel; everything inside fusion computations is
    counted (fusions themselves are not).
  * HBM bytes: counted per *top-level* op (fusion = one kernel):
    operands + result, with slice-like special cases (dynamic-slice /
    gather read only the slice; in-place dynamic-update-slice fusions
    write only the update).
  * collectives: operand bytes per op, times the multiplier.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_INT_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_SLICE_READ = {"dynamic-slice", "gather", "slice"}
_SLICE_WRITE = {"dynamic-update-slice", "scatter"}


def _parse_type(type_str: str) -> Tuple[int, List[List[int]]]:
    """-> (total bytes, list of dims-lists)."""
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append(ds)
    return total, shapes


def _numel(type_str: str) -> int:
    n_total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    line: str


def _parse_computations(hlo_text: str):
    comps: Dict[str, List[_Instr]] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and (
            line.startswith("%") or line.startswith("ENTRY")
        ):
            is_entry = line.startswith("ENTRY")
            tok = line.split()[1] if is_entry else line
            name = tok.split("(")[0].strip().lstrip("%").rstrip()
            current = name
            comps[current] = []
            if is_entry:
                entry = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _DEF_RE.match(line)
        if m:
            comps[current].append(_Instr(m.group(1), m.group(2), m.group(3), line))
    return comps, entry


def _operand_names(line: str, opcode: str) -> List[str]:
    idx = line.find(opcode + "(")
    if idx < 0:
        return []
    args = line[idx + len(opcode) + 1 :]
    depth, end = 1, 0
    for end, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    args = args[:end]
    names = []
    depth = 0
    cur = []
    for ch in args + ",":
        if ch == "," and depth == 0:
            piece = "".join(cur).strip()
            cur = []
            if piece:
                names.append(piece.split(" ")[-1].lstrip("%"))
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        cur.append(ch)
    return names


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    coll: Dict[str, dict]

    @property
    def coll_bytes(self) -> float:
        return float(sum(v["bytes"] for v in self.coll.values()))


def analyze_hlo(hlo_text: str) -> HloStats:
    comps, entry = _parse_computations(hlo_text)

    # global symbol table: name -> (bytes, shapes)
    table: Dict[str, Tuple[int, List[List[int]]]] = {}
    for instrs in comps.values():
        for ins in instrs:
            table[ins.name] = _parse_type(ins.type_str)

    # which computations are fusion bodies (their bytes are internal)
    fusion_bodies = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode == "fusion":
                m = _CALLED_RE.search(ins.line)
                if m:
                    fusion_bodies.add(m.group(1))

    # multipliers over the call graph
    mult: Dict[str, float] = {entry: 1.0} if entry else {}
    order = [entry] if entry else list(comps)
    seen = set(order)
    while order:
        cur = order.pop(0)
        for ins in comps.get(cur, ()):
            wm = _WHILE_RE.search(ins.line)
            callees: List[Tuple[str, float]] = []
            if wm:
                cond, body = wm.groups()
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = 1
                    for c_ins in comps.get(cond, ()):
                        for mm in _INT_CONST_RE.finditer(c_ins.line):
                            trips = max(trips, int(mm.group(1)))
                callees.append((body, trips))
            else:
                bm = _BRANCHES_RE.search(ins.line)
                if bm:
                    for b in bm.group(1).split(","):
                        callees.append((b.strip().lstrip("%"), 1.0))
                else:
                    cm = _CALLED_RE.search(ins.line)
                    if cm and ins.opcode not in ("all-reduce", "reduce",
                                                 "reduce-scatter", "scatter",
                                                 "reduce-window", "sort",
                                                 "select-and-scatter"):
                        callees.append((cm.group(1), 1.0))
            for callee, factor in callees:
                if callee in comps:
                    mult[callee] = mult.get(callee, 0.0) + mult.get(cur, 0.0) * factor
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    flops = 0.0
    hbm = 0.0
    coll: Dict[str, dict] = {}

    for cname, instrs in comps.items():
        w = mult.get(cname, 0.0)
        if w <= 0:
            continue
        in_fusion = cname in fusion_bodies
        for ins in instrs:
            op = ins.opcode
            # ---- flops -----------------------------------------------------
            if op in ("dot", "convolution"):
                res_n = _numel(ins.type_str)
                k = 1
                cm = _CONTRACT_RE.search(ins.line)
                if cm:
                    ops = _operand_names(ins.line, op)
                    if ops and ops[0] in table:
                        lhs_shapes = table[ops[0]][1]
                        if lhs_shapes:
                            dims = lhs_shapes[0]
                            for ci in cm.group(1).split(","):
                                if ci and int(ci) < len(dims):
                                    k *= dims[int(ci)]
                flops += w * 2.0 * res_n * k
            elif op == "reduce":
                ops = _operand_names(ins.line, op)
                n = table.get(ops[0], (0, []))[0] if ops else 0
                flops += w * n  # ~1 flop per input element (bytes->elems ok)
            elif op not in _NO_TRAFFIC and op != "fusion":
                flops += w * _numel(ins.type_str)

            # ---- collectives -----------------------------------------------
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    ops = _operand_names(ins.line, op)
                    b = sum(table.get(o, (0, []))[0] for o in ops)
                    ent = coll.setdefault(c, {"bytes": 0, "count": 0})
                    ent["bytes"] += int(w * b)
                    ent["count"] += int(w)
                    break

            # ---- HBM traffic (top-level kernels only) -----------------------
            if in_fusion or op in _NO_TRAFFIC:
                continue
            res_b = _parse_type(ins.type_str)[0]
            ops = _operand_names(ins.line, op)
            op_bytes = [table.get(o, (0, []))[0] for o in ops]
            if op in _SLICE_READ:
                traffic = 2 * res_b
            elif op in _SLICE_WRITE:
                upd = op_bytes[1] if len(op_bytes) > 1 else res_b
                traffic = 2 * upd
            elif op == "fusion":
                body = None
                m = _CALLED_RE.search(ins.line)
                if m:
                    body = m.group(1)
                has_dus = body in comps and any(
                    i.opcode in _SLICE_WRITE for i in comps[body]
                )
                if has_dus:
                    # in-place update kernel: aliased big operand + result
                    # are not (re)written; traffic ~ the small operands
                    small = [b for b in op_bytes if b != res_b]
                    traffic = 2 * sum(small) if small else 2 * res_b
                else:
                    traffic = sum(op_bytes) + res_b
            else:
                traffic = sum(op_bytes) + res_b
            hbm += w * traffic

    return HloStats(flops=flops, hbm_bytes=hbm, coll=coll)
