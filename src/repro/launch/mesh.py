"""Production mesh construction.

Single pod:  (16, 16)    axes ("data", "model")     = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as a function (never a module-level constant) so importing this
module touches no jax device state -- required because the dry-run driver
must set XLA_FLAGS before the first jax device query.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwargs when this jax has them (>= 0.5), else nothing.

    Older jax releases have neither ``jax.sharding.AxisType`` nor the
    ``axis_types`` parameter on ``jax.make_mesh``; Auto is their only
    behavior anyway, so omitting the kwarg is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = len(jax.devices())
    need = 512 if multi_pod else 256
    if ndev < need:
        # scaled-down stand-in for fast local iteration (same axis names);
        # the real dry-run uses xla_force_host_platform_device_count=512.
        if multi_pod:
            shape = (2, 2, ndev // 4)
        else:
            shape = (2, ndev // 2)
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
