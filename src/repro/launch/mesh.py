"""Production mesh construction.

Single pod:  (16, 16)    axes ("data", "model")     = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as a function (never a module-level constant) so importing this
module touches no jax device state -- required because the dry-run driver
must set XLA_FLAGS before the first jax device query.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = len(jax.devices())
    need = 512 if multi_pod else 256
    if ndev < need:
        # scaled-down stand-in for fast local iteration (same axis names);
        # the real dry-run uses xla_force_host_platform_device_count=512.
        if multi_pod:
            shape = (2, 2, ndev // 4)
        else:
            shape = (2, ndev // 2)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
