"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch x shape x mesh) cell, all in seconds:

  compute    = HLO_FLOPs_per_device   / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device   / HBM_bandwidth_per_chip
  collective = collective_bytes_per_device / ICI_link_bandwidth

FLOPs/bytes come from ``compiled.cost_analysis()`` (the SPMD-partitioned
per-device module).  Collective bytes are *not* in cost_analysis: we parse
the post-optimization HLO text, build a symbol table of instruction
result sizes, and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (values given by the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Peak rates of one chip, the two roofline ceilings.

    ``peak_flops`` and ``hbm_bw`` bound the compute and memory terms of a
    stage's roofline time (``max(flops / peak_flops, bytes / hbm_bw)``).
    The tpu/gpu defaults below are datasheet numbers; the cpu entry is
    *measured* on the repo's benchmark runner class by
    :mod:`repro.launch.calibrate` (a jitted gemm / stream micro-bench).
    Deployments on different hardware override via ``REPRO_PEAK_FLOPS`` /
    ``REPRO_HBM_BW``, or set ``REPRO_CALIBRATE=1`` to have
    :func:`repro.obs.cost.hardware_spec` run the calibration itself once
    per process.
    """

    name: str
    peak_flops: float  # flops/s
    hbm_bw: float  # bytes/s


BACKEND_SPECS = {
    # TPU v5e: the assignment's numbers (same constants as the module
    # globals the dry-run roofline uses).
    "tpu": HardwareSpec("tpu-v5e", PEAK_FLOPS, HBM_BW),
    # A100-40GB-class: 19.5 TF/s f32 tensor, 1.55 TB/s HBM2e.
    "gpu": HardwareSpec("gpu-a100", 19.5e12, 1.555e12),
    # Measured on the single-core CI runner class this repo benches on,
    # via ``python -m repro.launch.calibrate`` (median of repeated jitted
    # 1024^2 f32 gemm / 256 MiB stream passes): ~125 GFLOP/s, ~4.5 GB/s.
    # The old nominal entry guessed the bandwidth ~10x too high (50 GB/s
    # is a many-channel server socket, not one pinned core).  Re-measure
    # with the same command when the runner class changes, or override
    # per-machine via REPRO_PEAK_FLOPS / REPRO_HBM_BW / REPRO_CALIBRATE=1
    # (see repro.obs.cost.hardware_spec).
    "cpu": HardwareSpec("cpu-calibrated", 1.25e11, 4.5e9),
}


def backend_spec(backend: str) -> HardwareSpec:
    """Per-backend peak rates (falls back to the cpu placeholder)."""
    return BACKEND_SPECS.get(backend, BACKEND_SPECS["cpu"])


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\("
)


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples summed."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_INT_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_computations(hlo_text: str):
    """Split HLO text into computations; returns ({name: [lines]}, entry)."""
    comps: Dict[str, list] = {}
    current = None
    entry = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and (
            line.startswith("%") or line.startswith("ENTRY")
        ):
            is_entry = line.startswith("ENTRY")
            tok = line.split()[1] if is_entry else line.split("(")[0].strip()
            name = tok.split("(")[0].strip().lstrip("%").rstrip()
            current = name
            comps[current] = []
            if is_entry:
                entry = current
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return comps, entry


def _trip_count(line: str, cond_lines) -> int:
    """Trip count of a while: prefer XLA's known_trip_count backend config,
    fall back to the largest integer constant in the loop condition
    (scans compare the counter against the length)."""
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    best = 1
    for ln in cond_lines:
        for mm in _INT_CONST_RE.finditer(ln):
            best = max(best, int(mm.group(1)))
    return best


def _collective_bytes_in(lines, sizes) -> Dict[str, dict]:
    stats: Dict[str, dict] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        _, _, opcode = m.groups()
        base = None
        for c in _COLLECTIVES:
            if opcode == c or opcode == c + "-start":
                base = c
                break
        if base is None:
            continue
        idx = line.find(opcode + "(")
        args = line[idx + len(opcode) + 1 :]
        depth, end = 1, 0
        for end, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args = args[:end]
        op_bytes = 0
        for piece in _split_top(args):
            piece = piece.strip()
            tb = _type_bytes(piece)
            if tb:
                op_bytes += tb
            else:
                ref = piece.lstrip("%").split(" ")[-1].lstrip("%")
                op_bytes += sizes.get(ref, 0)
        ent = stats.setdefault(base, {"bytes": 0, "count": 0})
        ent["bytes"] += op_bytes
        ent["count"] += 1
    return stats


def collective_bytes(hlo_text: str) -> Dict[str, dict]:
    """Collective operand bytes from post-optimization HLO text.

    Loop-aware: collectives inside ``while`` bodies (scanned layers!) are
    multiplied by the loop trip count, propagated through nested loops and
    called computations -- a static parse would undercount a scanned
    24-layer model by 24x.
    """
    comps, entry = _parse_computations(hlo_text)
    # symbol table of result sizes across all computations (names unique)
    sizes: Dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                sizes[m.group(1)] = _type_bytes(m.group(2))

    # multiplier propagation over the call graph
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry is None:  # fallback: flat scan
        return _collective_bytes_in(hlo_text.splitlines(), sizes)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        cur = order.pop(0)
        for line in comps.get(cur, ()):
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = _trip_count(line, comps.get(cond, ()))
                if body in comps:
                    mult[body] = mult.get(body, 0.0) + mult[cur] * trips
                    if body not in seen:
                        seen.add(body)
                        order.append(body)
                continue
            bm = _BRANCHES_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b in comps:
                        mult[b] = mult.get(b, 0.0) + mult[cur]
                        if b not in seen:
                            seen.add(b)
                            order.append(b)
                continue
            cm = _CALLED_RE.search(line)
            if cm and "fusion(" not in line:
                callee = cm.group(1)
                if callee in comps:
                    mult[callee] = mult.get(callee, 0.0) + mult[cur]
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    total: Dict[str, dict] = {}
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w <= 0:
            continue
        local = _collective_bytes_in(lines, sizes)
        for op, ent in local.items():
            agg = total.setdefault(op, {"bytes": 0, "count": 0})
            agg["bytes"] += int(ent["bytes"] * w)
            agg["count"] += int(ent["count"] * w)
    return total


def _split_top(s: str):
    depth = 0
    cur = []
    for ch in s:
        if ch == "," and depth == 0:
            yield "".join(cur)
            cur = []
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        cur.append(ch)
    if cur:
        yield "".join(cur)


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    bytes_accessed: float  # per-device
    coll_bytes: float  # per-device
    coll_detail: dict
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(
    cost: dict,
    hlo_text: str,
    chips: int,
    model_flops_global: float,
) -> Roofline:
    """Roofline terms from the loop-aware HLO analyzer (hlo_stats); the XLA
    cost_analysis dict is kept only as a cross-reference (it counts while
    bodies once -- see hlo_stats docstring)."""
    from .hlo_stats import analyze_hlo

    st = analyze_hlo(hlo_text)
    flops = float(st.flops)
    bytes_acc = float(st.hbm_bytes)
    coll = st.coll
    cbytes = float(st.coll_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf_per_dev = model_flops_global / chips
    useful = mf_per_dev / flops if flops > 0 else 0.0
    return Roofline(
        flops=flops,
        bytes_accessed=bytes_acc,
        coll_bytes=cbytes,
        coll_detail=coll,
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        bottleneck=bottleneck,
        model_flops=model_flops_global,
        useful_ratio=useful,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS (analytic "useful flops") per shape kind
# ---------------------------------------------------------------------------


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: routed top-k + shared experts
    only; hybrid: the shared attention block is touched once per
    application, i.e. n_layers/attn_every times)."""
    total = cfg.params_count()
    if cfg.n_experts:
        mlp_one = cfg.d_model * cfg.d_ff * (3 if cfg.gated_mlp else 2)
        n_blocks = cfg.n_layers
        routed_all = cfg.n_experts * mlp_one * n_blocks
        routed_active = cfg.top_k * mlp_one * n_blocks
        return total - routed_all + routed_active
    if cfg.family == "hybrid" and cfg.attn_every:
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
        attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + hd * cfg.n_heads * d
        shared = attn + 3 * d * f
        n_apps = cfg.n_layers // cfg.attn_every
        return total + (n_apps - 1) * shared
    return total


def model_flops(cfg, shape) -> float:
    """6 N D for training, 2 N D for inference forward passes."""
    n_act = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * tokens
