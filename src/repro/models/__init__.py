"""Model substrate: composable JAX model definitions for all assigned
architectures (dense / MoE / RWKV6 / Mamba2-hybrid / enc-dec families)."""

from .api import SHAPES, ModelConfig, ShapeSpec, dp_axes, get_family, supports_shape

__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "dp_axes",
    "get_family",
    "supports_shape",
]
