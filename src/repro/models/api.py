"""Model/config API: ModelConfig, ShapeSpec, and the family dispatch.

Every assigned architecture is a ``ModelConfig`` (see ``repro.configs``).
``get_family(cfg)`` returns the module implementing the family protocol:

    init(cfg, rng)                         -> params pytree
    loss(cfg, params, batch, rng)          -> (scalar loss, metrics dict)
    forward(cfg, params, batch)            -> logits
    init_cache(cfg, batch, max_len)        -> decode cache pytree
    decode_step(cfg, params, cache, batch) -> (logits, new cache)
    input_specs(cfg, shape)                -> dict of ShapeDtypeStruct
    param_pspecs(cfg, params)              -> PartitionSpec pytree
    cache_pspecs(cfg, cache)               -> PartitionSpec pytree
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # default d_model // n_heads
    act: str = "silu"
    gated_mlp: bool = True
    norm: str = "rms"
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding-window attention
    tie_embeddings: bool = False
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    expert_sharding: str = "ep"  # "ep" (experts on model axis) | "tp"
    router_aux_coef: float = 0.01
    moe_group: int = 512  # token group size for GShard-style dispatch
    # --- RWKV6 ---------------------------------------------------------------
    rwkv_head_dim: int = 64
    rwkv_lora: int = 32
    # --- Mamba2 / hybrid -----------------------------------------------------
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0  # hybrid: shared attention block every N layers
    # --- encoder-decoder -------------------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # --- VLM stub ---------------------------------------------------------------
    n_patches: int = 0  # precomputed patch embeddings prepended to text
    # --- execution knobs ---------------------------------------------------------
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    scan_layers: bool = True
    ssm_chunk: int = 64
    scan_dtype: str = "float32"  # dtype of the SaP-scan tensors (bf16 halves
    # the chunked-recurrence HBM traffic at reduced cumsum precision)
    attn_block_k: int = 512
    kernel_impl: Optional[str] = None  # None -> repro.kernels.default_impl()

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up for clean sharding on any model axis <= 512
        (standard production trick; logits are sliced back in the loss)."""
        return -(-self.vocab // 512) * 512

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def cdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.compute_dtype]

    def params_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        if self.family in ("dense", "moe", "encdec"):
            mlp = d * f * (3 if self.gated_mlp else 2)
            if self.n_experts:
                routed = self.n_experts * mlp
                shared = self.n_shared_experts * mlp
                router = d * self.n_experts
                blk = attn + routed + shared + router
            else:
                blk = attn + mlp
            n_blocks = self.n_layers + self.n_enc_layers
            extra = self.n_enc_layers * attn  # cross-attention (rough)
            return v * d * (1 if self.tie_embeddings else 2) + n_blocks * blk + extra
        if self.family == "rwkv":
            att = 4 * d * d + 2 * d * self.rwkv_lora * 6
            ffn = 2 * d * f + d * d
            return v * d * 2 + self.n_layers * (att + ffn)
        if self.family == "hybrid":
            din = self.ssm_expand * d
            h = din // self.ssm_head_dim
            mix = d * (2 * din + 2 * self.ssm_state + h) + din * d
            # mamba layers have no MLP; one shared attn+MLP block total
            shared = attn + d * f * 3
            return v * d * 2 + self.n_layers * mix + shared
        raise ValueError(self.family)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def dp_axes(mesh):
    """Data-parallel mesh axes present on this mesh: ("pod","data") on the
    multi-pod production mesh, ("data",) on one pod, None on a 1-device
    test mesh."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def dp_axes_for(mesh, batch: int):
    """dp_axes, but only if ``batch`` divides across them (long_500k has
    global_batch=1: the batch dimension is replicated)."""
    dp = dp_axes(mesh)
    if dp is None:
        return None
    axes = dp if isinstance(dp, tuple) else (dp,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dp if batch % size == 0 else None


def get_family(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        from . import transformer

        return transformer
    if cfg.family == "rwkv":
        from . import rwkv

        return rwkv
    if cfg.family == "hybrid":
        from . import mamba

        return mamba
    if cfg.family == "encdec":
        from . import whisper

        return whisper
    raise ValueError(f"unknown family {cfg.family!r}")


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k decode requires a sub-quadratic sequence mixer: SSM/linear
    attention state or a sliding window.  Pure full-attention archs skip it
    (documented in DESIGN.md 'Arch-applicability')."""
    if shape.name != "long_500k":
        return True
    return cfg.family in ("rwkv", "hybrid") or cfg.window is not None
