"""Shared neural-net layers: norms, RoPE, attention, MLPs, embeddings.

Pure-function style: parameters are dict pytrees, every layer is
``f(params, x, cfg-ish kwargs) -> y``.  Attention is a chunked
online-softmax ("flash") formulation in plain jnp so that 32k-token
prefill never materializes a (T, T) score matrix -- the working set per
step is (block_q, block_k), which is what the TPU kernel would tile into
VMEM.  Grouped-query attention and sliding windows are supported
everywhere (training, prefill and decode).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


def group_norm(x: jax.Array, w: jax.Array, b: jax.Array, groups: int, eps: float = 1e-5):
    """GroupNorm over the channel axis (used by RWKV6 head ln_x)."""
    dtype = x.dtype
    *lead, c = x.shape
    x = x.astype(jnp.float32).reshape(*lead, groups, c // groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, c)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, D); positions: (..., T) or (T,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def mlp(params: dict, x: jax.Array, act: str = "silu", gated: bool = True):
    """SwiGLU-style (gated) or plain 2-layer MLP.

    gated:   params = {wi: (D, 2F) fused gate|up, wo: (F, D)}
    plain:   params = {wi: (D, F),              wo: (F, D)}
    """
    wi = params["wi"].astype(x.dtype)
    wo = params["wo"].astype(x.dtype)
    h = x @ wi
    if gated:
        g, up = jnp.split(h, 2, axis=-1)
        h = _act(act, g) * up
    else:
        h = _act(act, h)
    return h @ wo


# ---------------------------------------------------------------------------
# Flash attention (chunked online softmax), GQA + causal/SWA masks
# ---------------------------------------------------------------------------


NEG_INF = -1e30  # finite: -inf - -inf = NaN breaks online softmax for
# (q-row, kv-block) pairs that are fully masked (e.g. sliding windows)


def _mask_bias(
    q_pos: jax.Array,  # (Tq,)
    k_pos: jax.Array,  # (Tk,)
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def flash_attention(
    q: jax.Array,  # (B, Hq, Tq, Dh)
    k: jax.Array,  # (B, Hk, Tk, Dh)
    v: jax.Array,  # (B, Hk, Tk, Dh)
    causal: bool = True,
    window: Optional[int] = None,
    block_k: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Chunked online-softmax attention; never materializes (Tq, Tk).

    GQA: Hq must be a multiple of Hk; query heads are grouped.
    ``q_offset``: absolute position of q[0] (for cached decode/prefill).
    """
    b, hq, tq, dh = q.shape
    hk, tk = k.shape[1], k.shape[2]
    g = hq // hk
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = q.reshape(b, hk, g, tq, dh)

    nblk = -(-tk // block_k)
    pad = nblk * block_k - tk
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        kp, vp = k, v
    kb = kp.reshape(b, hk, nblk, block_k, dh)
    vb = vp.reshape(b, hk, nblk, block_k, dh)

    q_pos = q_offset + jnp.arange(tq)

    def kv_block(carry, blk):
        m_run, l_run, acc = carry
        kj, vj, j = blk
        k_pos = j * block_k + jnp.arange(block_k)
        valid = k_pos < tk
        bias = _mask_bias(q_pos, k_pos, causal, window)
        bias = jnp.where(valid[None, :], bias, NEG_INF)
        # scores: (B, Hk, G, Tq, Ck)
        s = jnp.einsum("bhgtd,bhcd->bhgtc", qg.astype(jnp.float32), kj.astype(jnp.float32))
        s = s * scale + bias
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgtc,bhcd->bhgtd", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hk, g, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hk, g, tq, dh), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        kv_block,
        (m0, l0, a0),
        (
            jnp.moveaxis(kb, 2, 0),
            jnp.moveaxis(vb, 2, 0),
            jnp.arange(nblk),
        ),
    )
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return out.reshape(b, hq, tq, dh).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, Hq, 1, Dh)
    k_cache: jax.Array,  # (B, Hk, S, Dh)
    v_cache: jax.Array,  # (B, Hk, S, Dh)
    cur_len: jax.Array,  # scalar or (B,) number of valid cache entries
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffered) KV cache."""
    b, hq, _, dh = q.shape
    hk, s = k_cache.shape[1], k_cache.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, dh)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    scores = jnp.einsum(
        "bhgd,bhsd->bhgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(s)
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (b,))
    valid = pos[None, :] < cur[:, None]
    if window is not None:
        valid &= pos[None, :] >= cur[:, None] - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def split_rngs(rng, n):
    return list(jax.random.split(rng, n))
