"""Mamba-2 / Zamba2 hybrid family.

Zamba2 structure (simplified but shape-faithful, see DESIGN.md): a backbone
of Mamba-2 (SSD) blocks with one *shared* transformer block (attention +
MLP, single set of weights) applied every ``cfg.attn_every`` layers.
Layers are grouped into segments of ``attn_every`` so the whole model is
two nested ``lax.scan``s -- no per-layer branching in the HLO.

The SSD recurrence is solved with the split-and-parallelize chunked scan
(``repro.kernels.ssd_chunk``) -- the same SaP pattern as the WKV kernel,
but with scalar per-head decay making it MXU-friendly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops

from .api import ModelConfig, ShapeSpec, dp_axes, dp_axes_for
from .layers import apply_rope, decode_attention, flash_attention, mlp, rms_norm


def _dims(cfg: ModelConfig):
    din = cfg.ssm_expand * cfg.d_model
    h = din // cfg.ssm_head_dim
    return din, h, cfg.ssm_state, cfg.ssm_head_dim


def _n_segments(cfg: ModelConfig):
    if cfg.attn_every and cfg.attn_every > 0:
        assert cfg.n_layers % cfg.attn_every == 0
        return cfg.n_layers // cfg.attn_every, cfg.attn_every
    return 1, cfg.n_layers


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_mamba_block(cfg: ModelConfig, rng) -> dict:
    d = cfg.d_model
    din, h, n, hd = _dims(cfg)
    conv_dim = din + 2 * n
    ks = jax.random.split(rng, 6)
    nrm = jax.random.normal
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "in_proj": nrm(ks[0], (d, 2 * din + 2 * n + h), jnp.float32) / jnp.sqrt(d),
        "conv_w": nrm(ks[1], (cfg.conv_width, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((din,), jnp.float32),
        "out_proj": nrm(ks[2], (din, d), jnp.float32) / jnp.sqrt(din),
    }


def _init_shared_attn(cfg: ModelConfig, rng) -> dict:
    d = cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(rng, 6)
    nrm = jax.random.normal
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "wq": nrm(ks[0], (d, cfg.n_heads * hd), jnp.float32) / jnp.sqrt(d),
        "wk": nrm(ks[1], (d, cfg.n_kv_heads * hd), jnp.float32) / jnp.sqrt(d),
        "wv": nrm(ks[2], (d, cfg.n_kv_heads * hd), jnp.float32) / jnp.sqrt(d),
        "wo": nrm(ks[3], (cfg.n_heads * hd, d), jnp.float32)
        / jnp.sqrt(cfg.n_heads * hd),
        "mlp": {
            "wi": nrm(ks[4], (d, 2 * cfg.d_ff), jnp.float32) / jnp.sqrt(d),
            "wo": nrm(ks[5], (cfg.d_ff, d), jnp.float32) / jnp.sqrt(cfg.d_ff),
        },
    }


def init(cfg: ModelConfig, rng) -> dict:
    k_e, k_b, k_s, k_h = jax.random.split(rng, 4)
    blocks = jax.vmap(lambda r: _init_mamba_block(cfg, r))(
        jax.random.split(k_b, cfg.n_layers)
    )
    vp = cfg.vocab_padded
    return {
        "embed": jax.random.normal(k_e, (vp, cfg.d_model), jnp.float32) * 0.02,
        "blocks": blocks,
        "shared_attn": _init_shared_attn(cfg, k_s),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": jax.random.normal(k_h, (cfg.d_model, vp), jnp.float32)
        * 0.02,
    }


# ---------------------------------------------------------------------------
# Mamba2 block (sequence form)
# ---------------------------------------------------------------------------


def _split_proj(cfg, proj):
    din, h, n, hd = _dims(cfg)
    z, xs, b, c, dt = jnp.split(proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], -1)
    return z, xs, b, c, dt


def _mamba_fwd(cfg: ModelConfig, p: dict, x: jax.Array, state: dict):
    """x: (B, T, D).  state: {conv: (B, W-1, conv_dim), ssm: (B, H, N, P)}."""
    bsz, t, d = x.shape
    din, h, n, hd = _dims(cfg)
    res = x
    x = rms_norm(x, p["ln"])
    proj = x @ p["in_proj"].astype(x.dtype)  # (B, T, 2din+2n+h)
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)

    # depthwise causal conv over [xs|B|C] with carried state
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)  # (B, T, conv_dim)
    w = cfg.conv_width
    hist = jnp.concatenate([state["conv"].astype(x.dtype), xbc], axis=1)
    conv = sum(
        hist[:, i : i + t] * p["conv_w"][i].astype(x.dtype) for i in range(w)
    ) + p["conv_b"].astype(x.dtype)
    conv = jax.nn.silu(conv)
    conv_state_out = hist[:, t : t + w - 1] if t >= w - 1 else jnp.concatenate(
        [state["conv"][:, t:], xbc], axis=1
    )
    xs, bmat, cmat = jnp.split(conv, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    loga = -jnp.exp(p["a_log"])[None, None, :] * dt  # (B, T, H) <= 0
    sdt = jnp.bfloat16 if cfg.scan_dtype == "bfloat16" else jnp.float32
    xh = xs.reshape(bsz, t, h, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    xh = (xh * dt.transpose(0, 2, 1)[..., None]).astype(sdt)  # fold dt in
    bh = jnp.broadcast_to(bmat[:, None].astype(sdt), (bsz, h, t, n))
    ch = jnp.broadcast_to(cmat[:, None].astype(sdt), (bsz, h, t, n))
    la = loga.transpose(0, 2, 1).astype(jnp.float32)  # (B, H, T)

    y, ssm_out = kops.ssd(
        xh, bh, ch, la, state["ssm"].astype(jnp.float32),
        chunk=min(cfg.ssm_chunk, t), impl=cfg.kernel_impl,
    )
    y = y.astype(jnp.float32) + p["d_skip"][None, :, None, None] * xh.astype(
        jnp.float32
    )  # skip connection
    y = y.transpose(0, 2, 1, 3).reshape(bsz, t, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    state_out = {"conv": conv_state_out.astype(state["conv"].dtype),
                 "ssm": ssm_out.astype(state["ssm"].dtype)}
    return res + out, state_out


# ---------------------------------------------------------------------------
# Shared attention block
# ---------------------------------------------------------------------------


def _shared_attn_fwd(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    bsz, t, d = x.shape
    hd = cfg.head_dim
    h1 = rms_norm(x, p["ln1"])
    q = (h1 @ p["wq"].astype(x.dtype)).reshape(bsz, t, cfg.n_heads, hd)
    k = (h1 @ p["wk"].astype(x.dtype)).reshape(bsz, t, cfg.n_kv_heads, hd)
    v = (h1 @ p["wv"].astype(x.dtype)).reshape(bsz, t, cfg.n_kv_heads, hd)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    o = flash_attention(q, k, v.transpose(0, 2, 1, 3), causal=True,
                        block_k=cfg.attn_block_k)
    o = o.transpose(0, 2, 1, 3).reshape(bsz, t, cfg.n_heads * hd)
    x = x + o @ p["wo"].astype(x.dtype)
    h2 = rms_norm(x, p["ln2"])
    return x + mlp(p["mlp"], h2, cfg.act, True)


# ---------------------------------------------------------------------------
# Model-level API
# ---------------------------------------------------------------------------


def _zero_state(cfg: ModelConfig, batch: int):
    din, h, n, hd = _dims(cfg)
    conv_dim = din + 2 * n
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_dim),
                          jnp.float32),
        "ssm": jnp.zeros((cfg.n_layers, batch, h, n, hd), jnp.float32),
    }


def _seg_tree(cfg, tree):
    ns, sl = _n_segments(cfg)
    return jax.tree.map(lambda a: a.reshape(ns, sl, *a.shape[1:]), tree)


def _unseg_tree(cfg, tree):
    return jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), tree)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, state=None):
    cdt = cfg.cdtype
    bsz, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    state = state if state is not None else _zero_state(cfg, bsz)
    positions = jnp.arange(t)
    ns, sl = _n_segments(cfg)
    blocks_seg = _seg_tree(cfg, params["blocks"])
    state_seg = _seg_tree(cfg, state)

    def layer_body(x, scanned):
        p_blk, st = scanned
        x, st_out = _mamba_fwd(cfg, p_blk, x, st)
        return x, st_out

    if cfg.remat != "none":
        layer_body = jax.checkpoint(layer_body)

    def segment_body(x, scanned):
        p_seg, st_seg = scanned
        x, st_out = jax.lax.scan(layer_body, x, (p_seg, st_seg))
        if cfg.attn_every:
            x = _shared_attn_fwd(cfg, params["shared_attn"], x, positions)
        return x, st_out

    x, state_out = jax.lax.scan(segment_body, x, (blocks_seg, state_seg))
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"].astype(cdt)
    return logits, _unseg_tree(cfg, state_out)


def loss(cfg: ModelConfig, params: dict, batch: dict, rng=None):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1, : cfg.vocab].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - picked).mean()
    return nll, {"nll": nll, "aux": jnp.zeros(())}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, prefilled: int = 0):
    cache = _zero_state(cfg, batch)
    if cfg.attn_every:
        ns, _ = _n_segments(cfg)
        hd = cfg.head_dim
        s = min(max_len, cfg.window) if cfg.window else max_len
        cache["attn_k"] = jnp.zeros((ns, batch, cfg.n_kv_heads, s, hd), cfg.cdtype)
        cache["attn_v"] = jnp.zeros((ns, batch, cfg.n_kv_heads, s, hd), cfg.cdtype)
        cache["len"] = jnp.asarray(prefilled, jnp.int32)
    return cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    cdt = cfg.cdtype
    bsz = tokens.shape[0]
    hd = cfg.head_dim
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(cdt)[:, None, :]
    cur = cache.get("len", jnp.asarray(0, jnp.int32))
    positions = cur[None].astype(jnp.int32)
    ns, sl = _n_segments(cfg)
    blocks_seg = _seg_tree(cfg, params["blocks"])
    mstate_seg = _seg_tree(cfg, {"conv": cache["conv"], "ssm": cache["ssm"]})

    def layer_body(x, scanned):
        p_blk, st = scanned
        x, st_out = _mamba_fwd(cfg, p_blk, x, st)
        return x, st_out

    def segment_body(x, scanned):
        p_seg, st_seg, k_c, v_c = scanned
        x, st_out = jax.lax.scan(layer_body, x, (p_seg, st_seg))
        if not cfg.attn_every:
            return x, (st_out, k_c, v_c)
        p = params["shared_attn"]
        s_cache = k_c.shape[2]
        slot = cur % s_cache
        h1 = rms_norm(x, p["ln1"])
        q = (h1 @ p["wq"].astype(cdt)).reshape(bsz, 1, cfg.n_heads, hd)
        k = (h1 @ p["wk"].astype(cdt)).reshape(bsz, 1, cfg.n_kv_heads, hd)
        v = (h1 @ p["wv"].astype(cdt)).reshape(bsz, 1, cfg.n_kv_heads, hd)
        q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
        k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
        v = v.transpose(0, 2, 1, 3)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, 0, slot, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, 0, slot, 0))
        o = decode_attention(q, k_c, v_c, jnp.minimum(cur + 1, s_cache))
        o = o.transpose(0, 2, 1, 3).reshape(bsz, 1, cfg.n_heads * hd)
        x = x + o @ p["wo"].astype(cdt)
        h2 = rms_norm(x, p["ln2"])
        x = x + mlp(p["mlp"], h2, cfg.act, True)
        return x, (st_out, k_c, v_c)

    if cfg.attn_every:
        scanned = (blocks_seg, mstate_seg, cache["attn_k"], cache["attn_v"])
    else:
        dummy = jnp.zeros((ns, 1, 1, 1, 1), cdt)
        scanned = (blocks_seg, mstate_seg, dummy, dummy)
    x, (mstate_out, k_out, v_out) = jax.lax.scan(segment_body, x, scanned)
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].astype(cdt))[:, 0, : cfg.vocab]
    new_cache = dict(_unseg_tree(cfg, mstate_out))
    if cfg.attn_every:
        new_cache["attn_k"] = k_out
        new_cache["attn_v"] = v_out
        new_cache["len"] = cur + 1
    return logits, new_cache


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    din, h, n, hd_s = _dims(cfg)
    conv_dim = din + 2 * n
    cache = {
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_layers, b, cfg.conv_width - 1, conv_dim), jnp.float32
        ),
        "ssm": jax.ShapeDtypeStruct((cfg.n_layers, b, h, n, hd_s), jnp.float32),
    }
    if cfg.attn_every:
        ns, _ = _n_segments(cfg)
        sc = min(s, cfg.window) if cfg.window else s
        kv = jax.ShapeDtypeStruct((ns, b, cfg.n_kv_heads, sc, cfg.head_dim), cfg.cdtype)
        cache["attn_k"] = kv
        cache["attn_v"] = kv
        cache["len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32), "cache": cache}


def param_pspecs(cfg: ModelConfig, mesh) -> dict:
    blk = {
        "ln": P(None, None),
        "in_proj": P(None, None, "model"),
        "conv_w": P(None, None, "model"),
        "conv_b": P(None, "model"),
        "a_log": P(None, None),
        "dt_bias": P(None, None),
        "d_skip": P(None, None),
        "out_norm": P(None, "model"),
        "out_proj": P(None, "model", None),
    }
    shared = {
        "ln1": P(None),
        "ln2": P(None),
        "wq": P(None, "model"),
        "wk": P(None, "model"),
        "wv": P(None, "model"),
        "wo": P("model", None),
        "mlp": {"wi": P(None, "model"), "wo": P("model", None)},
    }
    return {
        "embed": P("model", None),
        "blocks": blk,
        "shared_attn": shared,
        "final_norm": P(None),
        "lm_head": P(None, "model"),
    }


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    dp = dp_axes_for(mesh, shape.global_batch)
    if shape.kind in ("train", "prefill"):
        return {"tokens": P(dp, None)}
    cache = {
        "conv": P(None, dp, None, "model"),
        "ssm": P(None, dp, "model", None, None),
    }
    if cfg.attn_every:
        model_size = mesh.shape.get("model", 1)
        if cfg.n_kv_heads % model_size == 0:
            kv = P(None, dp, "model", None, None)
        else:
            kv = P(None, dp, None, None, None)
        cache["attn_k"] = kv
        cache["attn_v"] = kv
        cache["len"] = P()
    return {"tokens": P(dp, None), "cache": cache}
