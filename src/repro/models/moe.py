"""Mixture-of-Experts FFN: top-k routing, capacity, shared experts.

GShard-style dense dispatch *within token groups*: tokens are split into
groups of ``cfg.moe_group``; inside each group they are one-hot scattered
into per-expert capacity buffers with einsums, so the whole layer is SPMD-
shardable with pjit (expert axis on "model" for EP, or expert-hidden axis
for TP -- ``ModelConfig.expert_sharding``).  Grouping bounds the dispatch
tensor to  tokens x group x top_k x capacity_factor  elements instead of
the quadratic-in-tokens ungrouped form.

Supports DeepSeek-MoE fine-grained routing (64 routed + 2 shared, top-6)
and Mixtral (8 routed, top-2).  Aux losses: Switch-style load-balance +
router z-loss, returned for accumulation across layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .api import ModelConfig
from .layers import _act


def _positions_in_expert(expert_idx: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each routed slot within its expert, order-preserving.
    expert_idx: (N,) -> (N,) ranks."""
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(ranks, expert_idx[:, None], axis=1)[:, 0]


def moe_mlp(cfg: ModelConfig, params: dict, x: jax.Array):
    """x: (B, S, D) -> (y, aux_loss).

    params:
      router   : (D, E)
      experts  : {wi: (E, D, 2F or F), wo: (E, F, D)}
      shared   : {wi: (D, s*2F), wo: (s*F, D)}        (optional)
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = b * s
    group = min(cfg.moe_group, tokens)
    ng = tokens // group
    assert ng * group == tokens, f"tokens={tokens} not divisible by group={group}"
    xg = x.reshape(ng, group, d)

    # ---- routing (computed in f32) -----------------------------------------
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (NG, G, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (NG, G, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ----------------------------------------------------------
    onehot_e = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (NG, G, k, E)
    frac = onehot_e.sum(axis=(0, 1, 2)) / (tokens * k)
    mean_prob = probs.mean(axis=(0, 1))
    lb_loss = e * jnp.sum(frac * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = cfg.router_aux_coef * lb_loss + 1e-3 * z_loss

    # ---- capacity + positions (per group) ------------------------------------
    cap = int(max(1, round(group * k * cfg.capacity_factor / e)))
    pos = jax.vmap(lambda idx: _positions_in_expert(idx.reshape(-1), e))(
        gate_idx
    )  # (NG, G*k)
    pos = pos.reshape(ng, group, k)
    keep = (pos < cap).astype(jnp.float32)
    pos_c = jnp.where(pos < cap, pos, 0)
    onehot_c = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32)  # (NG, G, k, C)

    cdt = cfg.cdtype
    disp = jnp.einsum("gtke,gtkc,gtk->gtec", onehot_e, onehot_c, keep).astype(cdt)
    combine = jnp.einsum(
        "gtke,gtkc,gtk->gtec", onehot_e, onehot_c, keep * gate_vals
    ).astype(cdt)

    # ---- expert compute --------------------------------------------------------
    wi = params["experts"]["wi"].astype(cdt)  # (E, D, 2F|F)
    wo = params["experts"]["wo"].astype(cdt)  # (E, F, D)
    xe = jnp.einsum("gtec,gtd->gecd", disp, xg.astype(cdt))
    h = jnp.einsum("gecd,edf->gecf", xe, wi)
    if cfg.gated_mlp:
        gte, up = jnp.split(h, 2, axis=-1)
        h = _act(cfg.act, gte) * up
    ye = jnp.einsum("gecf,efd->gecd", h, wo)  # (NG, E, C, D)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)

    # ---- shared (always-on) experts ----------------------------------------------
    if cfg.n_shared_experts > 0:
        wi_s = params["shared"]["wi"].astype(cdt)
        wo_s = params["shared"]["wo"].astype(cdt)
        hs = jnp.einsum("gtd,dh->gth", xg.astype(cdt), wi_s)
        if cfg.gated_mlp:
            g2, up2 = jnp.split(hs, 2, axis=-1)
            hs = _act(cfg.act, g2) * up2
        y = y + jnp.einsum("gth,hd->gtd", hs, wo_s)

    return y.reshape(b, s, d).astype(x.dtype), aux


def init_moe(cfg: ModelConfig, rng, dtype=jnp.float32) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k_r, k_i, k_o, k_si, k_so = jax.random.split(rng, 5)
    wi_cols = 2 * f if cfg.gated_mlp else f
    params = {
        "router": jax.random.normal(k_r, (d, e), dtype) * 0.02,
        "experts": {
            "wi": jax.random.normal(k_i, (e, d, wi_cols), dtype) / jnp.sqrt(d),
            "wo": jax.random.normal(k_o, (e, f, d), dtype) / jnp.sqrt(f),
        },
    }
    if cfg.n_shared_experts > 0:
        fs = f * cfg.n_shared_experts
        params["shared"] = {
            "wi": jax.random.normal(k_si, (d, 2 * fs if cfg.gated_mlp else fs), dtype)
            / jnp.sqrt(d),
            "wo": jax.random.normal(k_so, (fs, d), dtype) / jnp.sqrt(fs),
        }
    return params
