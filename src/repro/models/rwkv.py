"""RWKV6 ("Finch") family: attention-free LM with data-dependent decay.

The WKV recurrence is the repo's flagship internal consumer of the paper's
technique: it is a block-bidiagonal linear system solved with the
split-and-parallelize chunked scan (``repro.kernels.wkv_chunk``) -- see
DESIGN.md "SaP-scan".  Faithful RWKV6 structure: data-dependent token-shift
(ddlerp with a small LoRA), data-dependent per-channel decay
w = exp(-exp(w0 + lora(x))), bonus term u, grouped head LayerNorm, gated
output; ReLU^2 channel mixing.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops

from .api import ModelConfig, ShapeSpec, dp_axes, dp_axes_for
from .layers import group_norm, rms_norm


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, rng) -> dict:
    d, f, lr = cfg.d_model, cfg.d_ff, cfg.rwkv_lora
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    ks = jax.random.split(rng, 16)
    n = jax.random.normal
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "att": {
            # ddlerp mixing coefficients + LoRA (5 targets: w, k, v, r, g)
            "x_maa": jnp.zeros((d,), jnp.float32),
            "maa": jnp.zeros((5, d), jnp.float32),
            "maa_w1": n(ks[0], (d, 5 * lr), jnp.float32) * 0.01,
            "maa_w2": n(ks[1], (5, lr, d), jnp.float32) * 0.01,
            # data-dependent decay
            "w0": jnp.full((d,), -4.0, jnp.float32),
            "wd1": n(ks[2], (d, lr), jnp.float32) * 0.01,
            "wd2": n(ks[3], (lr, d), jnp.float32) * 0.01,
            "u": n(ks[4], (h, hd), jnp.float32) * 0.1,  # "time_faaaa"
            "wr": n(ks[5], (d, d), jnp.float32) / jnp.sqrt(d),
            "wk": n(ks[6], (d, d), jnp.float32) / jnp.sqrt(d),
            "wv": n(ks[7], (d, d), jnp.float32) / jnp.sqrt(d),
            "wg": n(ks[8], (d, d), jnp.float32) / jnp.sqrt(d),
            "wo": n(ks[9], (d, d), jnp.float32) / jnp.sqrt(d),
            "ln_x_w": jnp.ones((d,), jnp.float32),
            "ln_x_b": jnp.zeros((d,), jnp.float32),
        },
        "ffn": {
            "k_maa": jnp.zeros((d,), jnp.float32),
            "r_maa": jnp.zeros((d,), jnp.float32),
            "wk": n(ks[10], (d, f), jnp.float32) / jnp.sqrt(d),
            "wv": n(ks[11], (f, d), jnp.float32) / jnp.sqrt(f),
            "wr": n(ks[12], (d, d), jnp.float32) / jnp.sqrt(d),
        },
    }


def init(cfg: ModelConfig, rng) -> dict:
    k_e, k_b, k_h = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda r: _init_block(cfg, r))(
        jax.random.split(k_b, cfg.n_layers)
    )
    vp = cfg.vocab_padded
    return {
        "embed": jax.random.normal(k_e, (vp, cfg.d_model), jnp.float32) * 0.02,
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": jax.random.normal(k_h, (cfg.d_model, vp), jnp.float32)
        * 0.02,
    }


# ---------------------------------------------------------------------------
# Block forward (sequence form)
# ---------------------------------------------------------------------------


def _ddlerp(p_att, x, xx):
    """RWKV6 data-dependent token-shift: 5 mixed variants of x (w,k,v,r,g)."""
    sx = xx - x  # (B, T, D)
    xbase = x + sx * p_att["x_maa"].astype(x.dtype)
    lo = jnp.tanh(xbase @ p_att["maa_w1"].astype(x.dtype))  # (B, T, 5*lr)
    b, t, _ = lo.shape
    lo = lo.reshape(b, t, 5, -1)
    delta = jnp.einsum("btfl,fld->btfd", lo, p_att["maa_w2"].astype(x.dtype))
    mix = p_att["maa"].astype(x.dtype)[None, None] + delta  # (B, T, 5, D)
    return x[:, :, None, :] + sx[:, :, None, :] * mix  # (B, T, 5, D)


def _time_mix(cfg: ModelConfig, p_att: dict, x: jax.Array, shift_in, wkv_in):
    """x: (B, T, D).  shift_in: (B, D) last token of previous step.
    Returns (out, shift_out, wkv_out)."""
    b, t, d = x.shape
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    xx = jnp.concatenate([shift_in[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    mixed = _ddlerp(p_att, x, xx)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]

    logw = -jnp.exp(
        p_att["w0"].astype(jnp.float32)
        + (jnp.tanh(xw @ p_att["wd1"].astype(x.dtype)) @ p_att["wd2"].astype(x.dtype))
        .astype(jnp.float32)
    )  # (B, T, D) <= 0
    r = (xr @ p_att["wr"].astype(x.dtype)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = (xk @ p_att["wk"].astype(x.dtype)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (xv @ p_att["wv"].astype(x.dtype)).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    g = xg @ p_att["wg"].astype(x.dtype)
    lw = logw.reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    sdt = jnp.bfloat16 if cfg.scan_dtype == "bfloat16" else jnp.float32
    o, wkv_out = kops.wkv6(
        r.astype(sdt),
        k.astype(sdt),
        v.astype(sdt),
        lw.astype(sdt),
        p_att["u"].astype(jnp.float32),
        wkv_in.astype(jnp.float32),
        chunk=min(cfg.ssm_chunk, t),
        impl=cfg.kernel_impl,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d).astype(x.dtype)
    o = group_norm(o, p_att["ln_x_w"], p_att["ln_x_b"], groups=h)
    o = (o * jax.nn.silu(g)) @ p_att["wo"].astype(x.dtype)
    return o, x[:, -1], wkv_out.astype(wkv_in.dtype)


def _channel_mix(p_ffn: dict, x: jax.Array, shift_in):
    xx = jnp.concatenate([shift_in[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)
    sx = xx - x
    xk = x + sx * p_ffn["k_maa"].astype(x.dtype)
    xr = x + sx * p_ffn["r_maa"].astype(x.dtype)
    kk = jax.nn.relu(xk @ p_ffn["wk"].astype(x.dtype)) ** 2
    kv = kk @ p_ffn["wv"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p_ffn["wr"].astype(x.dtype)) * kv, x[:, -1]


def _block_fwd(cfg, p_blk, x, state):
    h1 = rms_norm(x, p_blk["ln1"])
    att, s_att, wkv = _time_mix(cfg, p_blk["att"], h1, state["att_shift"], state["wkv"])
    x = x + att
    h2 = rms_norm(x, p_blk["ln2"])
    ffn, s_ffn = _channel_mix(p_blk["ffn"], h2, state["ffn_shift"])
    x = x + ffn
    return x, {"att_shift": s_att, "ffn_shift": s_ffn, "wkv": wkv}


# ---------------------------------------------------------------------------
# Model-level API
# ---------------------------------------------------------------------------


def _zero_state(cfg: ModelConfig, batch: int):
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    return {
        "att_shift": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
        "ffn_shift": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.float32),
    }


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, state=None):
    cdt = cfg.cdtype
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    state = state if state is not None else _zero_state(cfg, b)

    def body(x, scanned):
        p_blk, st = scanned
        x, st_out = _block_fwd(cfg, p_blk, x, st)
        return x, st_out

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, state_out = jax.lax.scan(body, x, (params["blocks"], state))
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"].astype(cdt)
    return logits, state_out


def loss(cfg: ModelConfig, params: dict, batch: dict, rng=None):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1, : cfg.vocab].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - picked).mean()
    return nll, {"nll": nll, "aux": jnp.zeros(())}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, prefilled: int = 0):
    return _zero_state(cfg, batch)


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    """Single-token step: runs the T=1 sequence form (state-carried)."""
    logits, state = forward_step(cfg, params, tokens, cache)
    return logits, state


def forward_step(cfg, params, tokens, state):
    cdt = cfg.cdtype
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(cdt)[:, None, :]

    def body(x, scanned):
        p_blk, st = scanned
        x, st_out = _block_fwd(cfg, p_blk, x, st)
        return x, st_out

    x, state_out = jax.lax.scan(body, x, (params["blocks"], state))
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"].astype(cdt))[:, 0, : cfg.vocab]
    return logits, state_out


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": {
            "att_shift": jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.d_model), jnp.float32),
            "ffn_shift": jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.d_model), jnp.float32),
            "wkv": jax.ShapeDtypeStruct((cfg.n_layers, b, h, hd, hd), jnp.float32),
        },
    }


def param_pspecs(cfg: ModelConfig, mesh) -> dict:
    blk = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "att": {
            "x_maa": P(None, None),
            "maa": P(None, None, None),
            "maa_w1": P(None, None, None),
            "maa_w2": P(None, None, None, None),
            "w0": P(None, None),
            "wd1": P(None, None, None),
            "wd2": P(None, None, None),
            "u": P(None, "model", None),
            "wr": P(None, None, "model"),
            "wk": P(None, None, "model"),
            "wv": P(None, None, "model"),
            "wg": P(None, None, "model"),
            "wo": P(None, "model", None),
            "ln_x_w": P(None, None),
            "ln_x_b": P(None, None),
        },
        "ffn": {
            "k_maa": P(None, None),
            "r_maa": P(None, None),
            "wk": P(None, None, "model"),
            "wv": P(None, "model", None),
            "wr": P(None, None, "model"),
        },
    }
    return {
        "embed": P("model", None),
        "blocks": blk,
        "final_norm": P(None),
        "lm_head": P(None, "model"),
    }


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    dp = dp_axes_for(mesh, shape.global_batch)
    if shape.kind in ("train", "prefill"):
        return {"tokens": P(dp, None)}
    return {
        "tokens": P(dp, None),
        "cache": {
            "att_shift": P(None, dp, None),
            "ffn_shift": P(None, dp, None),
            "wkv": P(None, dp, "model", None, None),
        },
    }
