"""Sequence parallelism for the SaP-scan (SSM/WKV recurrences).

Long-context *prefill* of the recurrent architectures shards the sequence
axis across devices.  This is the distributed version of the paper's
split: each device solves its local block of the (block-bidiagonal)
recurrence system, then the inter-device coupling -- the paper's reduced
system, exact for triangular systems -- is resolved by a chain of
``ppermute`` steps carrying (decayed) partial states:

    r_i <- r_{i-1} * D_{i-1} + s_{i-1}        (P-1 neighbor steps)

where s_j is shard j's local carry and D_j its total decay.  The chain is
exact (no truncation needed: triangular system), costs O(P) tiny
messages (one state tensor each), and the local work is the existing
chunked kernel -- so the communication structure is identical to the
SaP solver's preconditioner (DESIGN.md section 4).

The incoming state is folded in analytically (one extra elementwise +
one small einsum), so the local scan runs ONCE -- no second pass.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.kernels import ops as kops


def _prefix_chain(s_loc, ltot_exp, axes):
    """Exact cross-shard prefix of recurrence states.

    s_loc:    local carry with leading (B, H, ...) dims
    ltot_exp: per-shard total decay, broadcastable to s_loc
    Returns r = sum_{j < i} (prod_{j < l < i} D_l) s_j   on shard i.
    """
    n = axis_size(axes)
    perm = [(i, i + 1) for i in range(n - 1)]  # send to next; first gets 0

    def step(_, r):
        payload = r * ltot_exp + s_loc
        return jax.lax.ppermute(payload, axes, perm)

    r0 = jnp.zeros_like(s_loc)
    if n == 1:
        return r0
    return jax.lax.fori_loop(0, n - 1, step, r0)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (scalar per-head decay)
# ---------------------------------------------------------------------------


def sp_ssd_local(x, b, c, loga, axes, chunk: int = 64):
    """Per-shard body (call under shard_map; T is the sharded axis).

    x: (B, H, T_loc, P), b/c: (B, H, T_loc, N), loga: (B, H, T_loc).
    Returns (y, state_out) where state_out on the *last* shard is the
    global final state.
    """
    bsz, h, t_loc, pd = x.shape
    n_state = b.shape[-1]
    zeros = jnp.zeros((bsz, h, n_state, pd), jnp.float32)
    y0, s_loc = kops.ssd(x, b, c, loga, zeros, chunk=min(chunk, t_loc))

    ltot = loga.sum(axis=2)  # (B, H) total log-decay of this shard
    d_exp = jnp.exp(ltot)[..., None, None]  # broadcast to (B, H, N, P)
    r = _prefix_chain(s_loc, d_exp, axes)  # incoming state for this shard

    # fold the incoming state in analytically:
    # y_t += exp(Lcum_t) * (c_t @ r)
    lcum = jnp.cumsum(loga, axis=2)  # (B, H, T_loc)
    y_corr = jnp.exp(lcum)[..., None] * jnp.einsum(
        "bhtn,bhnp->bhtp", c.astype(jnp.float32), r
    )
    s_out = r * d_exp + s_loc
    # states differ per shard; stack them on a sharded leading axis --
    # the caller's global final state is stack[-1]
    return y0 + y_corr, s_out[None]


def sp_ssd(mesh, seq_axes=("data",)):
    """shard_map-wrapped sequence-parallel SSD.

    Inputs are globally-shaped with T sharded over ``seq_axes``; heads may
    additionally be sharded over 'model' by the caller's in_specs.
    Returns (y, states) with states: (n_shards, B, H, N, P); the global
    final state is ``states[-1]``.
    """
    ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    spec_t = P(None, None, ax, None)
    spec_l = P(None, None, ax)
    spec_s = P(ax, None, None, None, None)  # per-shard states, stacked
    fn = partial(sp_ssd_local, axes=seq_axes)
    return shard_map(
        lambda x, b, c, la: fn(x, b, c, la),
        mesh=mesh,
        in_specs=(spec_t, spec_t, spec_t, spec_l),
        out_specs=(spec_t, spec_s),
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# RWKV6 WKV (per-channel decay; state is (Dk, Dv) per head)
# ---------------------------------------------------------------------------


def sp_wkv6_local(r, k, v, logw, u, axes, chunk: int = 64):
    """Per-shard WKV6.  r/k/v/logw: (B, H, T_loc, D); u: (H, D).

    The current-token bonus u is shard-local (applies to position t only),
    so only the running state crosses shards.
    """
    bsz, h, t_loc, d = r.shape
    zeros = jnp.zeros((bsz, h, d, d), jnp.float32)
    o0, s_loc = kops.wkv6(r, k, v, logw, u, zeros, chunk=min(chunk, t_loc))

    ltot = logw.sum(axis=2)  # (B, H, D) per-channel total decay
    d_exp = jnp.exp(ltot)[..., None]  # (B, H, Dk, 1) acts on the k-dim
    rin = _prefix_chain(s_loc, d_exp, axes)

    # fold incoming state: o_t += (r_t * exp(Lprev_t)) @ r_in
    lcum = jnp.cumsum(logw, axis=2)
    lprev = jnp.concatenate(
        [jnp.zeros_like(lcum[:, :, :1]), lcum[:, :, :-1]], axis=2
    )
    o_corr = jnp.einsum(
        "bhtd,bhde->bhte", (r * jnp.exp(lprev)).astype(jnp.float32), rin
    )
    s_out = rin * d_exp + s_loc
    return o0 + o_corr, s_out[None]


def sp_wkv6(mesh, seq_axes=("data",)):
    """Returns (o, states) with states: (n_shards, B, H, Dk, Dv); the
    global final state is ``states[-1]``."""
    ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    spec_t = P(None, None, ax, None)
    spec_u = P(None, None)
    spec_s = P(ax, None, None, None, None)
    fn = partial(sp_wkv6_local, axes=seq_axes)
    return shard_map(
        lambda r, k, v, lw, u: fn(r, k, v, lw, u),
        mesh=mesh,
        in_specs=(spec_t, spec_t, spec_t, spec_t, spec_u),
        out_specs=(spec_t, spec_s),
        check_vma=False,
    )
