"""Generic decoder-only transformer LM: dense, MoE and VLM-stub families.

One implementation covers phi3-mini, stablelm-2, minitron, starcoder2,
mixtral, deepseek-moe and phi-3-vision (the vision frontend is a stub:
``input_specs`` supplies precomputed patch embeddings that are prepended
to the token embeddings, per the assignment).

Layers are stacked and iterated with ``jax.lax.scan`` (compile-time and
HLO-size control at 32-56 layers), with optional remat.  Attention is the
chunked flash formulation from ``layers.py``; decode uses a KV cache
(ring-buffered when a sliding window is configured).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import moe as moe_mod
from .api import ModelConfig, ShapeSpec, dp_axes, dp_axes_for
from .layers import apply_rope, decode_attention, flash_attention, mlp, rms_norm

# data-parallel activation axes are mesh-dependent: ("pod","data") on the
# multi-pod mesh, ("data",) on a single pod -- resolved via api.dp_axes().


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, rng) -> dict:
    d = cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(rng, 8)
    blk = {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "attn": {
            "wq": jax.random.normal(ks[0], (d, cfg.n_heads * hd), jnp.float32)
            / jnp.sqrt(d),
            "wk": jax.random.normal(ks[1], (d, cfg.n_kv_heads * hd), jnp.float32)
            / jnp.sqrt(d),
            "wv": jax.random.normal(ks[2], (d, cfg.n_kv_heads * hd), jnp.float32)
            / jnp.sqrt(d),
            "wo": jax.random.normal(ks[3], (cfg.n_heads * hd, d), jnp.float32)
            / jnp.sqrt(cfg.n_heads * hd),
        },
    }
    if cfg.n_experts > 0:
        blk["moe"] = moe_mod.init_moe(cfg, ks[4])
    else:
        wi_cols = 2 * cfg.d_ff if cfg.gated_mlp else cfg.d_ff
        blk["mlp"] = {
            "wi": jax.random.normal(ks[5], (d, wi_cols), jnp.float32) / jnp.sqrt(d),
            "wo": jax.random.normal(ks[6], (cfg.d_ff, d), jnp.float32)
            / jnp.sqrt(cfg.d_ff),
        }
    return blk


def init(cfg: ModelConfig, rng) -> dict:
    k_e, k_b, k_h = jax.random.split(rng, 3)
    blocks = jax.vmap(lambda r: _init_block(cfg, r))(
        jax.random.split(k_b, cfg.n_layers)
    )
    vp = cfg.vocab_padded
    params = {
        "embed": jax.random.normal(k_e, (vp, cfg.d_model), jnp.float32) * 0.02,
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_h, (cfg.d_model, vp), jnp.float32) * 0.02
        )
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attention(cfg: ModelConfig, p_attn: dict, x: jax.Array, positions: jax.Array):
    b, t, d = x.shape
    hd = cfg.head_dim
    q = (x @ p_attn["wq"].astype(x.dtype)).reshape(b, t, cfg.n_heads, hd)
    k = (x @ p_attn["wk"].astype(x.dtype)).reshape(b, t, cfg.n_kv_heads, hd)
    v = (x @ p_attn["wv"].astype(x.dtype)).reshape(b, t, cfg.n_kv_heads, hd)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    if cfg.kernel_impl == "pallas" and t % 128 == 0:
        # TPU deploy path: VMEM-resident flash kernel (see kernels/flash_attn)
        from repro.kernels.flash_attn import flash_attention_pallas

        o = flash_attention_pallas(
            q, k, v, causal=True, window=cfg.window, interpret=False
        )
    else:
        o = flash_attention(
            q, k, v, causal=True, window=cfg.window, block_k=cfg.attn_block_k
        )
    o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * hd)
    return o @ p_attn["wo"].astype(x.dtype)


def _block_fwd(cfg: ModelConfig, p_blk: dict, x: jax.Array, positions: jax.Array):
    h = rms_norm(x, p_blk["ln1"])
    x = x + _attention(cfg, p_blk["attn"], h, positions)
    h = rms_norm(x, p_blk["ln2"])
    if cfg.n_experts > 0:
        y, aux = moe_mod.moe_mlp(cfg, p_blk["moe"], h)
    else:
        y, aux = mlp(p_blk["mlp"], h, cfg.act, cfg.gated_mlp), 0.0
    return x + y, aux


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    patches: Optional[jax.Array] = None,
):
    """tokens: (B, S) int32; patches: (B, Pn, D) prepended (VLM stub).
    Returns (logits, aux_loss)."""
    cdt = cfg.cdtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if patches is not None:
        x = jnp.concatenate([patches.astype(cdt), x], axis=1)
    t = x.shape[1]
    positions = jnp.arange(t)

    def body(carry, p_blk):
        x, aux = carry
        x, aux_l = _block_fwd(cfg, p_blk, x, positions)
        return (x, aux + aux_l), None

    body = _maybe_remat(cfg, body)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            p_blk = jax.tree.map(lambda a: a[i], params["blocks"])
            (x, aux), _ = body((x, aux), p_blk)

    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head.astype(cdt)
    return logits, aux


def loss(cfg: ModelConfig, params: dict, batch: dict, rng=None):
    tokens = batch["tokens"]
    patches = batch.get("patches")
    logits, aux = forward(cfg, params, tokens, patches)
    if patches is not None:
        logits = logits[:, patches.shape[1] :]  # text region only
    tgt = tokens[:, 1:]
    lg = logits[:, :-1, : cfg.vocab].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - picked).mean()
    total = nll + aux
    return total, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Decode (KV cache; ring buffer under sliding window)
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.window) if cfg.window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, prefilled: int = 0):
    s = cache_len(cfg, max_len)
    hd = cfg.head_dim
    kv = lambda: jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, s, hd), cfg.cdtype)
    return {"k": kv(), "v": kv(), "len": jnp.asarray(prefilled, jnp.int32)}


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    """tokens: (B, 1) -> (logits (B, V), new cache)."""
    cdt = cfg.cdtype
    b = tokens.shape[0]
    hd = cfg.head_dim
    cur = cache["len"]
    s_cache = cache["k"].shape[3]
    slot = cur % s_cache  # == cur when un-windowed (cache sized to max_len)
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(cdt)[:, None, :]
    positions = cur[None].astype(jnp.int32)

    def body(carry, scanned):
        x = carry
        p_blk, k_c, v_c = scanned
        h = rms_norm(x, p_blk["ln1"])
        q = (h @ p_blk["attn"]["wq"].astype(cdt)).reshape(b, 1, cfg.n_heads, hd)
        k = (h @ p_blk["attn"]["wk"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (h @ p_blk["attn"]["wv"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, hd)
        q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
        k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
        v = v.transpose(0, 2, 1, 3)
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, 0, slot, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, 0, slot, 0))
        n_valid = jnp.minimum(cur + 1, s_cache)
        o = decode_attention(q, k_c, v_c, n_valid, window=None)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * hd)
        x = x + o @ p_blk["attn"]["wo"].astype(cdt)
        h2 = rms_norm(x, p_blk["ln2"])
        if cfg.n_experts > 0:
            y, _ = moe_mod.moe_mlp(cfg, p_blk["moe"], h2)
        else:
            y = mlp(p_blk["mlp"], h2, cfg.act, cfg.gated_mlp)
        return x + y, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"])
    )
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = (x @ head.astype(cdt))[:, 0, : cfg.vocab]
    new_cache = {"k": k_new, "v": v_new, "len": cur + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Specs & shardings
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.n_patches:
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), cfg.cdtype
            )
        return specs
    # decode: one token + cache of seq_len context
    sc = cache_len(cfg, s)
    hd = cfg.head_dim
    kv = jax.ShapeDtypeStruct((cfg.n_layers, b, cfg.n_kv_heads, sc, hd), cfg.cdtype)
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": {
            "k": kv,
            "v": kv,
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }


def _kv_heads_spec(cfg: ModelConfig, mesh, batch: int):
    """Shard KV heads on 'model' when divisible, else shard head_dim."""
    dp = dp_axes_for(mesh, batch)
    model_size = mesh.shape.get("model", 1)
    if cfg.n_kv_heads % model_size == 0:
        return P(None, dp, "model", None, None)
    if cfg.head_dim % model_size == 0:
        return P(None, dp, None, None, "model")
    return P(None, dp, None, None, None)


def param_pspecs(cfg: ModelConfig, mesh) -> dict:
    model_size = mesh.shape.get("model", 1)
    blk = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "attn": {
            "wq": P(None, None, "model"),
            "wk": P(None, None, "model"),
            "wv": P(None, None, "model"),
            "wo": P(None, "model", None),
        },
    }
    if cfg.n_experts > 0:
        if cfg.expert_sharding == "ep" and cfg.n_experts % model_size == 0:
            ex = {"wi": P(None, "model", None, None), "wo": P(None, "model", None, None)}
        else:
            ex = {"wi": P(None, None, None, "model"), "wo": P(None, None, "model", None)}
        blk["moe"] = {
            "router": P(None, None, None),
            "experts": ex,
        }
        if cfg.n_shared_experts > 0:
            blk["moe"]["shared"] = {
                "wi": P(None, None, "model"),
                "wo": P(None, "model", None),
            }
    else:
        blk["mlp"] = {"wi": P(None, None, "model"), "wo": P(None, "model", None)}
    specs = {
        "embed": P("model", None),
        "blocks": blk,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model")
    return specs


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    dp = dp_axes_for(mesh, shape.global_batch)
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": P(dp, None)}
        if cfg.n_patches:
            specs["patches"] = P(dp, None, None)
        return specs
    return {
        "tokens": P(dp, None),
        "cache": {
            "k": _kv_heads_spec(cfg, mesh, shape.global_batch),
            "v": _kv_heads_spec(cfg, mesh, shape.global_batch),
            "len": P(),
        },
    }
