"""Whisper-style encoder-decoder family (audio backbone, conv-frontend stub).

Per the assignment, the modality frontend is a STUB: ``input_specs``
supplies precomputed mel-frame embeddings (B, enc_seq, D) -- the two conv
layers of Whisper are outside scope.  The transformer backbone is
faithful: sinusoidal positions on the encoder, learned positions on the
decoder, pre-LN blocks with GELU MLPs, decoder cross-attention, and a
tied output head.  Decode uses a self-attention KV cache plus precomputed
cross-attention K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .api import ModelConfig, ShapeSpec, dp_axes, dp_axes_for
from .layers import decode_attention, flash_attention, layer_norm, mlp


def _sinusoids(length: int, channels: int) -> jax.Array:
    log_timescale = jnp.log(10_000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    t = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _attn_params(cfg, rng, cross=False):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(rng, 4)
    nrm = jax.random.normal
    return {
        "wq": nrm(ks[0], (d, cfg.n_heads * hd), jnp.float32) / jnp.sqrt(d),
        "wk": nrm(ks[1], (d, cfg.n_kv_heads * hd), jnp.float32) / jnp.sqrt(d),
        "wv": nrm(ks[2], (d, cfg.n_kv_heads * hd), jnp.float32) / jnp.sqrt(d),
        "wo": nrm(ks[3], (cfg.n_heads * hd, d), jnp.float32)
        / jnp.sqrt(cfg.n_heads * hd),
    }


def _ln(d):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _init_enc_block(cfg, rng):
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": _ln(d),
        "attn": _attn_params(cfg, k1),
        "ln2": _ln(d),
        "mlp": {
            "wi": jax.random.normal(k2, (d, cfg.d_ff), jnp.float32) / jnp.sqrt(d),
            "wo": jax.random.normal(k3, (cfg.d_ff, d), jnp.float32)
            / jnp.sqrt(cfg.d_ff),
        },
    }


def _init_dec_block(cfg, rng):
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "ln1": _ln(d),
        "self_attn": _attn_params(cfg, k1),
        "ln_x": _ln(d),
        "cross_attn": _attn_params(cfg, k2),
        "ln2": _ln(d),
        "mlp": {
            "wi": jax.random.normal(k3, (d, cfg.d_ff), jnp.float32) / jnp.sqrt(d),
            "wo": jax.random.normal(k4, (cfg.d_ff, d), jnp.float32)
            / jnp.sqrt(cfg.d_ff),
        },
    }


def init(cfg: ModelConfig, rng) -> dict:
    k_e, k_eb, k_db, k_p = jax.random.split(rng, 4)
    enc = jax.vmap(lambda r: _init_enc_block(cfg, r))(
        jax.random.split(k_eb, cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda r: _init_dec_block(cfg, r))(
        jax.random.split(k_db, cfg.n_layers)
    )
    return {
        "embed": jax.random.normal(k_e, (cfg.vocab_padded, cfg.d_model), jnp.float32)
        * 0.02,
        "pos_dec": jax.random.normal(k_p, (32_768, cfg.d_model), jnp.float32) * 0.01,
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_ln": _ln(cfg.d_model),
        "dec_ln": _ln(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _mha(cfg, p, xq, xkv, causal):
    b, tq, d = xq.shape
    hd = cfg.head_dim
    q = (xq @ p["wq"].astype(xq.dtype)).reshape(b, tq, cfg.n_heads, hd)
    k = (xkv @ p["wk"].astype(xq.dtype)).reshape(b, -1, cfg.n_kv_heads, hd)
    v = (xkv @ p["wv"].astype(xq.dtype)).reshape(b, -1, cfg.n_kv_heads, hd)
    o = flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        block_k=cfg.attn_block_k,
    )
    return o.transpose(0, 2, 1, 3).reshape(b, tq, cfg.n_heads * hd) @ p["wo"].astype(
        xq.dtype
    )


def encode(cfg: ModelConfig, params: dict, frames: jax.Array):
    """frames: (B, enc_seq, D) precomputed embeddings (conv stub)."""
    cdt = cfg.cdtype
    x = frames.astype(cdt) + _sinusoids(frames.shape[1], cfg.d_model).astype(cdt)

    def body(x, p_blk):
        h = layer_norm(x, p_blk["ln1"]["w"], p_blk["ln1"]["b"])
        x = x + _mha(cfg, p_blk["attn"], h, h, causal=False)
        h = layer_norm(x, p_blk["ln2"]["w"], p_blk["ln2"]["b"])
        x = x + mlp(p_blk["mlp"], h, "gelu", gated=False)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"])


def decode_train(cfg: ModelConfig, params: dict, tokens: jax.Array, enc: jax.Array):
    cdt = cfg.cdtype
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    x = x + params["pos_dec"][:t].astype(cdt)

    def body(x, p_blk):
        h = layer_norm(x, p_blk["ln1"]["w"], p_blk["ln1"]["b"])
        x = x + _mha(cfg, p_blk["self_attn"], h, h, causal=True)
        h = layer_norm(x, p_blk["ln_x"]["w"], p_blk["ln_x"]["b"])
        x = x + _mha(cfg, p_blk["cross_attn"], h, enc, causal=False)
        h = layer_norm(x, p_blk["ln2"]["w"], p_blk["ln2"]["b"])
        x = x + mlp(p_blk["mlp"], h, "gelu", gated=False)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
    return x @ params["embed"].T.astype(cdt)  # tied head


def forward(cfg: ModelConfig, params: dict, batch: dict):
    enc = encode(cfg, params, batch["frames"])
    return decode_train(cfg, params, batch["tokens"], enc), jnp.zeros(())


def loss(cfg: ModelConfig, params: dict, batch: dict, rng=None):
    logits, _ = forward(cfg, params, batch)
    tokens = batch["tokens"]
    tgt = tokens[:, 1:]
    lg = logits[:, :-1, : cfg.vocab].astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - picked).mean()
    return nll, {"nll": nll, "aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# Decode (cached)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, prefilled: int = 0):
    hd = cfg.head_dim
    kv = lambda s: jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, s, hd), cfg.cdtype)
    return {
        "self_k": kv(max_len),
        "self_v": kv(max_len),
        "cross_k": kv(cfg.enc_seq),
        "cross_v": kv(cfg.enc_seq),
        "len": jnp.asarray(prefilled, jnp.int32),
    }


def precompute_cross_kv(cfg: ModelConfig, params: dict, enc: jax.Array):
    """Build the cross-attention K/V cache once per request batch."""
    hd = cfg.head_dim
    b = enc.shape[0]

    def per_layer(p_blk, _):
        k = (enc @ p_blk["cross_attn"]["wk"].astype(enc.dtype)).reshape(
            b, -1, cfg.n_kv_heads, hd
        )
        v = (enc @ p_blk["cross_attn"]["wv"].astype(enc.dtype)).reshape(
            b, -1, cfg.n_kv_heads, hd
        )
        return None, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    _, (ck, cv) = jax.lax.scan(lambda c, p: per_layer(p, c), None, params["dec_blocks"])
    return ck, cv


def decode_step(cfg: ModelConfig, params: dict, cache: dict, tokens: jax.Array):
    cdt = cfg.cdtype
    b = tokens.shape[0]
    hd = cfg.head_dim
    cur = cache["len"]
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(cdt)[:, None, :]
    x = x + jax.lax.dynamic_slice(
        params["pos_dec"], (cur, 0), (1, cfg.d_model)
    ).astype(cdt)

    def body(x, scanned):
        p_blk, k_c, v_c, ck, cv = scanned
        h = layer_norm(x, p_blk["ln1"]["w"], p_blk["ln1"]["b"])
        q = (h @ p_blk["self_attn"]["wq"].astype(cdt)).reshape(b, 1, cfg.n_heads, hd)
        k = (h @ p_blk["self_attn"]["wk"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (h @ p_blk["self_attn"]["wv"].astype(cdt)).reshape(b, 1, cfg.n_kv_heads, hd)
        q, k, v = (z.transpose(0, 2, 1, 3) for z in (q, k, v))
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, 0, cur, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, 0, cur, 0))
        o = decode_attention(q, k_c, v_c, cur + 1)
        x = x + o.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ p_blk["self_attn"][
            "wo"
        ].astype(cdt)
        h = layer_norm(x, p_blk["ln_x"]["w"], p_blk["ln_x"]["b"])
        q2 = (h @ p_blk["cross_attn"]["wq"].astype(cdt)).reshape(b, 1, cfg.n_heads, hd)
        o2 = decode_attention(
            q2.transpose(0, 2, 1, 3), ck, cv, jnp.asarray(cfg.enc_seq, jnp.int32)
        )
        x = x + o2.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ p_blk["cross_attn"][
            "wo"
        ].astype(cdt)
        h = layer_norm(x, p_blk["ln2"]["w"], p_blk["ln2"]["b"])
        x = x + mlp(p_blk["mlp"], h, "gelu", gated=False)
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body,
        x,
        (params["dec_blocks"], cache["self_k"], cache["self_v"], cache["cross_k"],
         cache["cross_v"]),
    )
    x = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
    logits = (x @ params["embed"].T.astype(cdt))[:, 0, : cfg.vocab]
    new_cache = dict(cache)
    new_cache.update({"self_k": k_new, "self_v": v_new, "len": cur + 1})
    return logits, new_cache


# ---------------------------------------------------------------------------
# Specs & shardings
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {
            "frames": jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), cfg.cdtype),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    hd = cfg.head_dim
    kv = lambda sl: jax.ShapeDtypeStruct(
        (cfg.n_layers, b, cfg.n_kv_heads, sl, hd), cfg.cdtype
    )
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": {
            "self_k": kv(s),
            "self_v": kv(s),
            "cross_k": kv(cfg.enc_seq),
            "cross_v": kv(cfg.enc_seq),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }


def _attn_pspecs():
    return {
        "wq": P(None, None, "model"),
        "wk": P(None, None, "model"),
        "wv": P(None, None, "model"),
        "wo": P(None, "model", None),
    }


def param_pspecs(cfg: ModelConfig, mesh) -> dict:
    ln = {"w": P(None, None), "b": P(None, None)}
    enc = {
        "ln1": ln,
        "attn": _attn_pspecs(),
        "ln2": ln,
        "mlp": {"wi": P(None, None, "model"), "wo": P(None, "model", None)},
    }
    dec = {
        "ln1": ln,
        "self_attn": _attn_pspecs(),
        "ln_x": ln,
        "cross_attn": _attn_pspecs(),
        "ln2": ln,
        "mlp": {"wi": P(None, None, "model"), "wo": P(None, "model", None)},
    }
    return {
        "embed": P("model", None),
        "pos_dec": P(None, None),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_ln": {"w": P(None), "b": P(None)},
        "dec_ln": {"w": P(None), "b": P(None)},
    }


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    dp = dp_axes_for(mesh, shape.global_batch)
    if shape.kind in ("train", "prefill"):
        return {"frames": P(dp, None, None), "tokens": P(dp, None)}
    model_size = mesh.shape.get("model", 1)
    kv = (
        P(None, dp, "model", None, None)
        if cfg.n_kv_heads % model_size == 0
        else P(None, dp, None, None, None)
    )
    return {
        "tokens": P(dp, None),
        "cache": {
            "self_k": kv,
            "self_v": kv,
            "cross_k": kv,
            "cross_v": kv,
            "len": P(),
        },
    }
