"""Observability: lifecycle tracing, stage trees, Chrome/Perfetto export."""

from .trace import (
    NULL_SPAN,
    Span,
    Tracer,
    get_tracer,
    record,
    span,
    use_tracer,
)

__all__ = [
    "NULL_SPAN",
    "Span",
    "Tracer",
    "get_tracer",
    "record",
    "span",
    "use_tracer",
]
