"""Observability: lifecycle tracing, roofline cost accounting, telemetry."""

from .cost import (
    COMPILES,
    CompileLog,
    StageCost,
    cost_of,
    cost_of_compiled,
    device_memory_bytes,
    hardware_spec,
    install_compile_listener,
    solver_stage_costs,
    timed_compile,
)
from .trace import (
    NULL_SPAN,
    Span,
    Tracer,
    get_tracer,
    record,
    span,
    use_tracer,
)

__all__ = [
    "COMPILES",
    "CompileLog",
    "NULL_SPAN",
    "Span",
    "StageCost",
    "Tracer",
    "cost_of",
    "cost_of_compiled",
    "device_memory_bytes",
    "get_tracer",
    "hardware_spec",
    "install_compile_listener",
    "record",
    "solver_stage_costs",
    "span",
    "timed_compile",
    "use_tracer",
]
