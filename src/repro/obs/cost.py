"""Cost observatory: per-stage roofline accounting + compile/memory telemetry.

The tracer (:mod:`repro.obs.trace`) answers *where the seconds went*; this
module answers the two questions next to it:

1. **What should those seconds have been?**  Every jitted solver stage
   (the batched factor stages, the batched Krylov solve, the raw
   btf/bts/bcr kernels) is lowered ONCE per bucket shape and run through
   ``compiled.cost_analysis()`` plus the loop-aware
   :func:`repro.launch.hlo_stats.analyze_hlo` walk over the
   post-optimization HLO.  The result is a :class:`StageCost`: flops, HBM
   bytes, arithmetic intensity, and the roofline-predicted seconds
   ``max(flops / peak_flops, bytes / hbm_bw)`` under the current
   backend's :class:`~repro.launch.roofline.HardwareSpec`.  Dividing the
   roofline prediction by a measured wall time gives the
   achieved-vs-roofline fraction that ``BENCH_batched.json`` rows carry.

2. **How much compiling and memory is the serving path paying?**  A
   process-wide :class:`CompileLog` counts every XLA backend compile
   (ground truth via ``jax.monitoring``'s backend_compile event, with a
   :func:`timed_compile` fallback when the listener API is unavailable),
   attributing labeled compiles (`factor.batch` AOT misses, cost-layer
   lowerings) and emitting ``compile`` trace spans.
   :func:`device_memory_bytes` samples the live device footprint
   (``device.memory_stats()`` where the backend reports it -- TPU/GPU --
   falling back to summing ``jax.live_arrays()`` on CPU), which the
   engine folds into a ``peak_device_bytes`` watermark.

Import cycles: :mod:`repro.core.batched` imports the telemetry
primitives (:func:`timed_compile`) from here, so everything that reaches
back into the solver (:func:`solver_stage_costs`) imports lazily.

The loop-aware HLO walk multiplies ``while`` bodies by their trip count,
so a Krylov executable's cost is ~``maxiter`` sweeps.  Real solves stop
earlier: :meth:`StageCost.per_iteration` divides the cost back down so
callers can scale by the iterations a solve actually ran.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax

from ..launch.hlo_stats import analyze_hlo
from ..launch.roofline import HardwareSpec, backend_spec
from .trace import span

__all__ = [
    "COMPILES",
    "CompileLog",
    "StageCost",
    "cost_of",
    "cost_of_compiled",
    "device_memory_bytes",
    "hardware_spec",
    "install_compile_listener",
    "solver_stage_costs",
    "timed_compile",
]


# ---------------------------------------------------------------------------
# Hardware spec resolution
# ---------------------------------------------------------------------------


# Result of the one-shot REPRO_CALIBRATE=1 micro-benchmark; measured
# once per process the first time hardware_spec() needs it.
_CALIBRATED: Optional[HardwareSpec] = None
_CALIBRATED_LOCK = threading.Lock()


def hardware_spec(backend: Optional[str] = None) -> HardwareSpec:
    """The active backend's peak rates, with env overrides.

    ``REPRO_PEAK_FLOPS`` / ``REPRO_HBM_BW`` (floats, flops/s and bytes/s)
    override the per-backend defaults in
    :data:`repro.launch.roofline.BACKEND_SPECS` -- measured-machine
    calibration without touching code.  ``REPRO_CALIBRATE=1`` instead
    *measures* this machine's ceilings once per process via
    :func:`repro.launch.calibrate.calibrate` (a ~1 s gemm + stream
    micro-bench); explicit env numbers still win over the measurement.
    """
    spec = backend_spec(backend or jax.default_backend())
    if os.environ.get("REPRO_CALIBRATE") == "1":
        global _CALIBRATED
        with _CALIBRATED_LOCK:
            if _CALIBRATED is None:
                from ..launch.calibrate import calibrate

                _CALIBRATED = calibrate()
            spec = _CALIBRATED
    pf = os.environ.get("REPRO_PEAK_FLOPS")
    bw = os.environ.get("REPRO_HBM_BW")
    if pf or bw:
        spec = dataclasses.replace(
            spec,
            name=spec.name + "+env",
            peak_flops=float(pf) if pf else spec.peak_flops,
            hbm_bw=float(bw) if bw else spec.hbm_bw,
        )
    return spec


# ---------------------------------------------------------------------------
# Compile telemetry
# ---------------------------------------------------------------------------


class CompileLog:
    """Thread-safe process-wide compile counters.

    ``total_count`` / ``total_seconds`` are ground truth from the XLA
    backend-compile monitoring event (every jit cache miss in the
    process, not just instrumented call sites).  ``labels`` attributes
    the compiles that went through :func:`timed_compile` -- their wall
    time includes tracing + lowering, so a label's seconds can exceed its
    share of ``total_seconds``.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._count = 0
        self._seconds = 0.0
        self._labels: Dict[str, Dict[str, float]] = {}
        self.listener_installed = False

    def _on_event(self, seconds: float) -> None:
        with self._lock:
            self._count += 1
            self._seconds += seconds

    def _on_labeled(self, label: str, seconds: float) -> None:
        with self._lock:
            ent = self._labels.setdefault(label, {"count": 0, "seconds": 0.0})
            ent["count"] += 1
            ent["seconds"] += seconds
            if not self.listener_installed:
                # no monitoring API: the labeled sites are the best totals
                self._count += 1
                self._seconds += seconds

    def snapshot(self) -> dict:
        """``{"recompiles_total", "compile_seconds_total", "labels"}``."""
        with self._lock:
            return {
                "recompiles_total": self._count,
                "compile_seconds_total": self._seconds,
                "labels": {k: dict(v) for k, v in self._labels.items()},
            }

    def totals(self) -> Tuple[int, float]:
        """(compile count, cumulative compile seconds) observed so far."""
        with self._lock:
            return self._count, self._seconds


COMPILES = CompileLog()
_LISTENER_LOCK = threading.Lock()

# every backend compile fires this jax.monitoring duration event
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def install_compile_listener() -> bool:
    """Register the process-wide backend-compile listener (idempotent).

    Returns True when the ``jax.monitoring`` listener is active.  JAX
    offers registration but no removal, so this is once-per-process --
    the callback only bumps two counters under a lock.
    """
    with _LISTENER_LOCK:
        if COMPILES.listener_installed:
            return True
        try:
            from jax import monitoring

            def _listener(event: str, duration: float, **kw: Any) -> None:
                if event == _COMPILE_EVENT:
                    COMPILES._on_event(duration)

            monitoring.register_event_duration_secs_listener(_listener)
            COMPILES.listener_installed = True
        except Exception:  # pragma: no cover - older/stripped jax builds
            COMPILES.listener_installed = False
        return COMPILES.listener_installed


install_compile_listener()


@contextlib.contextmanager
def timed_compile(label: str, **attrs: Any):
    """Bracket a ``.lower().compile()`` (or first jit call): emits a
    ``compile`` trace span and attributes the wall time to ``label`` in
    :data:`COMPILES`.  The process totals come from the monitoring
    listener; this adds the *which call site* dimension.
    """
    t0 = time.perf_counter()
    with span("compile", label=label, **attrs):
        yield
    COMPILES._on_labeled(label, time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Device memory
# ---------------------------------------------------------------------------


def device_memory_bytes(device: Optional[Any] = None) -> int:
    """Current device memory footprint in bytes.

    Prefers the backend allocator's ``memory_stats()["bytes_in_use"]``
    (TPU/GPU); CPU backends report no allocator stats, so the fallback
    sums ``jax.live_arrays()`` -- live committed arrays, which is the
    watermark that matters for the solver's factorization cache.
    """
    devices = [device] if device is not None else jax.local_devices()
    total = 0
    reported = False
    for d in devices:
        try:
            ms = d.memory_stats()
        except Exception:
            ms = None
        if ms and "bytes_in_use" in ms:
            total += int(ms["bytes_in_use"])
            reported = True
    if reported:
        return total
    try:
        return int(sum(a.nbytes for a in jax.live_arrays()))
    except Exception:  # pragma: no cover - live_arrays unavailable
        return 0


# ---------------------------------------------------------------------------
# Stage cost records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageCost:
    """Roofline accounting of one compiled solver stage.

    ``flops`` / ``hbm_bytes`` come from the loop-aware HLO walk
    (:func:`~repro.launch.hlo_stats.analyze_hlo`); ``xla_flops`` /
    ``xla_bytes`` keep ``compiled.cost_analysis()`` as a cross-reference
    (it counts while bodies once, so it undercounts iterative stages).
    ``loop_iters`` marks costs that bake a while-loop trip count in
    (Krylov: ``maxiter`` sweeps) -- :meth:`per_iteration` removes it.
    """

    stage: str
    flops: float
    hbm_bytes: float
    intensity: float  # flops / hbm_bytes
    compute_s: float
    memory_s: float
    roofline_s: float  # max(compute_s, memory_s)
    bottleneck: str  # "compute" | "memory"
    hw: str
    xla_flops: float
    xla_bytes: float
    loop_iters: Optional[int] = None

    def scale(self, factor: float) -> "StageCost":
        """Linear rescale (e.g. per-batch-element cost x batch size)."""
        return dataclasses.replace(
            self,
            flops=self.flops * factor,
            hbm_bytes=self.hbm_bytes * factor,
            compute_s=self.compute_s * factor,
            memory_s=self.memory_s * factor,
            roofline_s=self.roofline_s * factor,
            xla_flops=self.xla_flops * factor,
            xla_bytes=self.xla_bytes * factor,
        )

    def per_iteration(self) -> "StageCost":
        """Cost of ONE loop sweep for stages with a baked-in trip count."""
        if not self.loop_iters or self.loop_iters <= 1:
            return self
        out = self.scale(1.0 / self.loop_iters)
        return dataclasses.replace(out, loop_iters=None)

    def achieved_fraction(self, measured_s: float) -> float:
        """roofline_s / measured_s: 1.0 = running at the hardware ceiling."""
        if measured_s <= 0.0:
            return float("nan")
        return self.roofline_s / measured_s

    def to_dict(self, measured_s: Optional[float] = None) -> dict:
        """JSON-ready record; includes roofline_frac when measured_s given."""
        d = {
            "stage": self.stage,
            "flops": float(self.flops),
            "hbm_bytes": float(self.hbm_bytes),
            "intensity": round(self.intensity, 4),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "roofline_s": self.roofline_s,
            "bottleneck": self.bottleneck,
            "hw": self.hw,
            "xla_flops": float(self.xla_flops),
            "xla_bytes": float(self.xla_bytes),
        }
        if self.loop_iters is not None:
            d["loop_iters"] = int(self.loop_iters)
        if measured_s is not None:
            d["measured_s"] = measured_s
            d["roofline_frac"] = round(self.achieved_fraction(measured_s), 6)
        return d


def _xla_cost(compiled) -> Tuple[float, float]:
    """(flops, bytes accessed) from ``compiled.cost_analysis()``; the jax
    0.4.x shape is a list with one dict per partition."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return 0.0, 0.0
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return 0.0, 0.0
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def cost_of_compiled(
    stage: str,
    compiled,
    hw: Optional[HardwareSpec] = None,
    loop_iters: Optional[int] = None,
) -> StageCost:
    """Roofline-account an already-compiled executable."""
    hw = hw or hardware_spec()
    st = analyze_hlo(compiled.as_text())
    xf, xb = _xla_cost(compiled)
    flops = float(st.flops)
    hbm = float(st.hbm_bytes)
    compute_s = flops / hw.peak_flops
    memory_s = hbm / hw.hbm_bw
    return StageCost(
        stage=stage,
        flops=flops,
        hbm_bytes=hbm,
        intensity=flops / hbm if hbm > 0 else 0.0,
        compute_s=compute_s,
        memory_s=memory_s,
        roofline_s=max(compute_s, memory_s),
        bottleneck="compute" if compute_s >= memory_s else "memory",
        hw=hw.name,
        xla_flops=xf,
        xla_bytes=xb,
        loop_iters=loop_iters,
    )


def cost_of(
    fn,
    *avals,
    stage: str = "stage",
    static: Optional[dict] = None,
    hw: Optional[HardwareSpec] = None,
    loop_iters: Optional[int] = None,
) -> StageCost:
    """Lower + compile ``fn`` on abstract ``avals`` and roofline-account it.

    ``fn`` may already be jit-wrapped (anything with ``.lower``);
    ``static`` passes static kwargs through to the lowering.  The compile
    is counted and spanned via :func:`timed_compile` under
    ``cost:<stage>``.
    """
    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    lowered = jfn.lower(*avals, **(static or {}))
    with timed_compile(f"cost:{stage}"):
        compiled = lowered.compile()
    return cost_of_compiled(stage, compiled, hw=hw, loop_iters=loop_iters)


# ---------------------------------------------------------------------------
# Solver stage costs (per bucket shape)
# ---------------------------------------------------------------------------

_SOLVER_COSTS: Dict[tuple, Dict[str, StageCost]] = {}
_SOLVER_COSTS_LOCK = threading.Lock()


def solver_stage_costs(
    bucket: Tuple[int, int, int],
    s: int = 1,
    opts=None,
    variant: Optional[str] = None,
    dtype=None,
) -> Dict[str, StageCost]:
    """Roofline costs of every solver stage for one bucket shape.

    ``bucket`` is the compiled shape ``(N', K', P)`` (the engine's
    currency, from :func:`repro.core.batched.bucket_shape`); ``s`` is the
    system-batch size the executables are lowered at.  Returns a dict of
    :class:`StageCost` keyed by stage:

      * ``"factor"`` -- the vmapped batched factor stages, compiled via
        the SAME AOT cache ``batch_factor`` executes from, so asking for
        the cost of a bucket the engine already served is free.
      * ``"krylov"`` -- the batched solve executable.  Its HLO cost bakes
        in ``maxiter`` sweeps (``loop_iters``); use ``per_iteration()``
        and scale by the iterations a solve actually ran.
      * ``"btf"`` / ``"bts"`` -- the raw block-tridiagonal kernels at the
        bucket's (P, M, K') partition grid (the factor/solve inner loop).
      * ``"bcr"`` -- the log-depth reduced-chain kernels, present when the
        variant solves an exact reduced system (``"E"``) with P > 1.

    Results are cached per (bucket, s, variant, relevant options,
    backend); repeated calls cost a dict lookup.
    """
    from ..core import batched
    from ..core.sap import SaPOptions

    nb, kb, p = bucket
    opts = opts or SaPOptions(p=p)
    if variant is None:
        variant = opts.variant if opts.variant != "auto" else "C"
    dtype = jax.numpy.dtype(dtype or jax.numpy.float32)
    hw = hardware_spec()
    key = (
        bucket, s, variant, batched._factor_key(opts),
        opts.tol, opts.maxiter, opts.use_cg, opts.iter_dtype, opts.solver,
        str(dtype), jax.default_backend(), hw.name,
    )
    with _SOLVER_COSTS_LOCK:
        hit = _SOLVER_COSTS.get(key)
    if hit is not None:
        return hit

    costs: Dict[str, StageCost] = {}
    bands = jax.ShapeDtypeStruct((s, nb, 2 * kb + 1), dtype)

    # -- factor: shared AOT executable (also serves batch_factor) ----------
    compiled = batched.factor_stages_compiled(
        kb, p, variant, batched._factor_key(opts), bands
    )
    costs["factor"] = cost_of_compiled("factor", compiled, hw=hw)

    # -- krylov: abstract factorization -> the batched solve executable ----
    stages = batched._factor_stages_fn(
        kb, p, variant, batched._factor_key(opts)
    )
    pc_struct, d_struct = jax.eval_shape(stages, bands)
    from ..core import sap as sap_mod
    from ..core.operators import BandedOperator
    from ..core.sap import SaPFactorization

    perm = jax.ShapeDtypeStruct((s, nb), jax.numpy.int32)
    fac = SaPFactorization(
        op=BandedOperator(band=bands, n=nb, k=kb),
        pc=pc_struct,
        b_perm=perm,
        x_perm=perm,
        n=nb,
        k=kb,
        tol=opts.tol,
        maxiter=opts.maxiter,
        use_cg=opts.use_cg,
        iter_dtype=opts.iter_dtype,
        solver=sap_mod.resolve_solver(opts.solver, opts.use_cg),
        d_factor=d_struct,
    )
    b_struct = jax.ShapeDtypeStruct((s, nb), dtype)
    lowered = batched._solve_batch.lower(fac, b_struct, record_history=False)
    with timed_compile("cost:krylov", bucket=f"{nb}x{kb}", s=s):
        krylov_exec = lowered.compile()
    costs["krylov"] = cost_of_compiled(
        "krylov", krylov_exec, hw=hw, loop_iters=opts.maxiter
    )

    # -- raw kernels at the bucket's partition grid ------------------------
    from ..kernels import ops as kops

    m = max(nb // (p * kb), 1)
    blk = jax.ShapeDtypeStruct((p, m, kb, kb), dtype)
    costs["btf"] = cost_of(
        lambda d, e, f: kops.block_tridiag_factor(d, e, f),
        blk, blk, blk, stage="btf", hw=hw,
    )
    fac_struct = jax.eval_shape(
        lambda d, e, f: kops.block_tridiag_factor(d, e, f), blk, blk, blk
    )
    rhs = jax.ShapeDtypeStruct((p, m, kb, 1), dtype)
    costs["bts"] = cost_of(
        lambda fc, b: kops.block_tridiag_solve(fc, b),
        fac_struct, rhs, stage="bts", hw=hw,
    )
    if variant == "E" and p > 1:
        m2 = p - 1
        blk2 = jax.ShapeDtypeStruct((m2, 2 * kb, 2 * kb), dtype)
        rhs2 = jax.ShapeDtypeStruct((m2, 2 * kb, 1), dtype)
        bcr_struct = jax.eval_shape(
            lambda d, e, f: kops.bcr_factor(d, e, f), blk2, blk2, blk2
        )
        bcr_f = cost_of(
            lambda d, e, f: kops.bcr_factor(d, e, f),
            blk2, blk2, blk2, stage="bcr", hw=hw,
        )
        bcr_s = cost_of(
            lambda fc, b: kops.bcr_solve(fc, b),
            bcr_struct, rhs2, stage="bcr", hw=hw,
        )
        # one record for the reduced-system sweep: factor + solve
        merged = dataclasses.replace(
            bcr_f,
            flops=bcr_f.flops + bcr_s.flops,
            hbm_bytes=bcr_f.hbm_bytes + bcr_s.hbm_bytes,
            compute_s=bcr_f.compute_s + bcr_s.compute_s,
            memory_s=bcr_f.memory_s + bcr_s.memory_s,
            xla_flops=bcr_f.xla_flops + bcr_s.xla_flops,
            xla_bytes=bcr_f.xla_bytes + bcr_s.xla_bytes,
        )
        total_f = merged.flops
        total_b = merged.hbm_bytes
        merged = dataclasses.replace(
            merged,
            intensity=total_f / total_b if total_b > 0 else 0.0,
            roofline_s=max(merged.compute_s, merged.memory_s),
            bottleneck="compute"
            if merged.compute_s >= merged.memory_s else "memory",
        )
        costs["bcr"] = merged

    with _SOLVER_COSTS_LOCK:
        _SOLVER_COSTS.setdefault(key, costs)
        return _SOLVER_COSTS[key]
