"""End-to-end solve tracing: nested spans, Chrome/Perfetto export, stage trees.

The tracer answers the question the source paper answers with its stage
tables: where does a solve spend its time — reordering (DB/CM), LU+SPIKE
factorization, or Krylov iteration?  Usage:

    from repro.obs import Tracer, use_tracer

    tracer = Tracer()
    with use_tracer(tracer):
        fac = factor(plan(a, opts))
        res = fac.solve(b)
    print(tracer.summary())
    tracer.export_chrome("trace.json")   # open at ui.perfetto.dev

Design constraints:

- **Zero overhead when disabled.**  The module-level ``span()`` helper
  returns a shared no-op singleton when no tracer is active (one global
  read + one ``is None`` check); instrumented code never pays for
  timestamps, dict churn, or lock traffic unless a tracer is installed.
- **Trace-safe.**  Instrumented functions also run under ``jax.jit`` /
  ``vmap`` (e.g. the batched factor stages).  Host-side timing of traced
  code is meaningless and attribute values would be tracers, so ``span()``
  degrades to the no-op span whenever JAX is mid-trace.
- **Thread-safe.**  Span nesting is tracked per-thread (the async serving
  drain thread traces concurrently with client threads); finished roots
  are collected under a lock.
- **Honest device timing.**  JAX dispatch is async even on CPU; a span
  that launches device work should call ``sp.sync(result)`` so the span
  exit blocks on the result before taking the end timestamp.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax

__all__ = [
    "NULL_SPAN",
    "Span",
    "Tracer",
    "get_tracer",
    "record",
    "span",
    "use_tracer",
]


def _under_jax_trace() -> bool:
    """True while JAX is abstractly tracing (jit/vmap/grad staging)."""
    try:
        return not jax.core.trace_state_clean()
    except Exception:  # pragma: no cover - compat with future jax layouts
        return False


def _jsonable(v: Any) -> Any:
    """Coerce an attribute value to something the trace_event format accepts."""
    if isinstance(v, bool) or v is None or isinstance(v, str):
        return v
    if isinstance(v, (int, float)):
        if isinstance(v, float) and not (v == v and abs(v) != float("inf")):
            return repr(v)  # NaN/inf are not valid strict JSON
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    try:  # numpy / jax scalars
        if getattr(v, "ndim", None) == 0:
            return _jsonable(v.item())
    except Exception:
        pass
    return str(v)


class _NullSpan:
    """Shared no-op span: every tracer API is a cheap constant method."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def __bool__(self) -> bool:
        return False

    def annotate(self, **attrs: Any) -> "_NullSpan":
        """No-op; mirrors Span.annotate."""
        return self

    def sync(self, value: Any) -> Any:
        """No-op passthrough; mirrors Span.sync."""
        return value

    @property
    def duration_s(self) -> float:
        """Always 0.0 for the disabled span."""
        return 0.0


NULL_SPAN = _NullSpan()


class Span:
    """A timed, attributed region.  Created via ``Tracer.span`` / ``span()``."""

    __slots__ = ("name", "attrs", "t0", "t1", "tid", "children", "_tracer", "_pending", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self.tid = 0
        self.children: List[Span] = []
        self._tracer = tracer
        self._pending: Any = None
        self._ann = None

    def __bool__(self) -> bool:
        return True

    def annotate(self, **attrs: Any) -> "Span":
        """Attach attributes after entry (e.g. values computed inside the span)."""
        self.attrs.update(attrs)
        return self

    def sync(self, value: Any) -> Any:
        """Register a pytree of device arrays to block on at span exit.

        Returns ``value`` unchanged so call sites can wrap an expression:
        ``res = sp.sync(fac.solve(b))``.
        """
        self._pending = value
        return value

    def __enter__(self) -> "Span":
        tracer = self._tracer
        self.tid = threading.get_ident()
        tracer._stack().append(self)
        if tracer.annotate_xla:
            try:
                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:  # pragma: no cover - profiler backend unavailable
                self._ann = None
        self.t0 = tracer.clock()
        return self

    def __exit__(self, *exc: Any) -> bool:
        if self._pending is not None and self._tracer.device_sync:
            try:
                jax.block_until_ready(self._pending)
            except Exception:
                pass
            self._pending = None
        self.t1 = self._tracer.clock()
        if self._ann is not None:
            try:
                self._ann.__exit__(*exc)
            except Exception:  # pragma: no cover
                pass
            self._ann = None
        self._tracer._finish(self)
        return False

    @property
    def duration_s(self) -> float:
        """Wall seconds between span open and close."""
        return max(self.t1 - self.t0, 0.0)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Span({self.name!r}, {self.duration_s * 1e3:.3f} ms, attrs={self.attrs})"


class Tracer:
    """Collects a forest of spans across threads.

    Parameters
    ----------
    enabled:
        When False every ``span()`` returns the no-op singleton; an
        instrumented code path costs one attribute read per span site.
    device_sync:
        When True (default), spans that registered a value via
        ``sp.sync(x)`` call ``jax.block_until_ready`` before taking the
        end timestamp, so durations reflect device completion rather than
        async dispatch.
    annotate_xla:
        When True, each host span also opens a
        ``jax.profiler.TraceAnnotation`` of the same name, so spans line
        up with XLA events inside ``jax.profiler.trace`` captures.
    """

    def __init__(
        self,
        enabled: bool = True,
        device_sync: bool = True,
        annotate_xla: bool = False,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.enabled = enabled
        self.device_sync = device_sync
        self.annotate_xla = annotate_xla
        self.clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._roots: List[Span] = []

    # -- collection ---------------------------------------------------------

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def span(self, name: str, **attrs: Any):
        """Open a nested span; use as a context manager."""
        if not self.enabled or _under_jax_trace():
            return NULL_SPAN
        return Span(self, name, attrs)

    def record(self, name: str, t0: float, t1: float, tid: Optional[int] = None, **attrs: Any) -> None:
        """Add a retroactive root span from externally captured timestamps.

        Timestamps must come from this tracer's clock (``tracer.now()``);
        the async service uses this to emit one span per request covering
        submit→resolve, which no single ``with`` block brackets.
        """
        if not self.enabled:
            return
        sp = Span(self, name, dict(attrs))
        sp.t0, sp.t1 = t0, t1
        sp.tid = threading.get_ident() if tid is None else tid
        with self._lock:
            self._roots.append(sp)

    def now(self) -> float:
        """Current timestamp on this tracer's clock (for ``record``)."""
        return self.clock()

    def _finish(self, sp: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        else:  # mis-nested exit (shouldn't happen); recover rather than corrupt
            try:
                stack.remove(sp)
            except ValueError:
                pass
        if stack:
            stack[-1].children.append(sp)
        else:
            with self._lock:
                self._roots.append(sp)

    # -- queries ------------------------------------------------------------

    def roots(self) -> List[Span]:
        """Top-level finished spans, ordered by start time."""
        with self._lock:
            return sorted(self._roots, key=lambda s: s.t0)

    def walk(self) -> Iterator[Span]:
        """All finished spans, depth-first."""
        def rec(sp: Span) -> Iterator[Span]:
            yield sp
            for c in sp.children:
                yield from rec(c)

        for r in self.roots():
            yield from rec(r)

    def find(self, name: str) -> List[Span]:
        """All finished spans with the given name."""
        return [s for s in self.walk() if s.name == name]

    def durations(self) -> Dict[str, float]:
        """Total seconds per span name (summed over occurrences)."""
        out: Dict[str, float] = {}
        for s in self.walk():
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out

    def clear(self) -> None:
        """Drop all recorded spans."""
        with self._lock:
            self._roots = []

    # -- exporters ----------------------------------------------------------

    def to_chrome_events(self) -> List[Dict[str, Any]]:
        """Span forest as Chrome trace_event ``B``/``E`` pairs (ts in µs)."""
        events: List[Dict[str, Any]] = []
        pid = os.getpid()
        events.append(
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": "repro.solve"}}
        )
        seen_tids = set()

        def emit(sp: Span) -> None:
            if sp.tid not in seen_tids:
                seen_tids.add(sp.tid)
                events.append(
                    {"name": "thread_name", "ph": "M", "pid": pid, "tid": sp.tid,
                     "args": {"name": f"thread-{sp.tid}"}}
                )
            ts0 = (sp.t0 - self._epoch) * 1e6
            ts1 = (sp.t1 - self._epoch) * 1e6
            events.append(
                {"name": sp.name, "ph": "B", "pid": pid, "tid": sp.tid, "ts": ts0,
                 "args": {k: _jsonable(v) for k, v in sp.attrs.items()}}
            )
            for c in sorted(sp.children, key=lambda s: s.t0):
                emit(c)
            events.append({"name": sp.name, "ph": "E", "pid": pid, "tid": sp.tid, "ts": ts1})

        for r in self.roots():
            emit(r)
        return events

    def export_chrome(self, path: str) -> str:
        """Write a Chrome/Perfetto trace_event JSON file; returns the path."""
        doc = {"traceEvents": self.to_chrome_events(), "displayTimeUnit": "ms"}
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return path

    def summary(self, min_frac: float = 0.0) -> str:
        """Human-readable stage tree: spans merged by name at each depth.

        ``min_frac`` hides merged nodes below that fraction of their parent.
        """
        lines = [f"{'span':<44} {'total':>12} {'count':>6} {'% parent':>9}"]

        def merge(spans: List[Span]) -> List[tuple]:
            groups: Dict[str, List[Span]] = {}
            order: List[str] = []
            for s in spans:
                if s.name not in groups:
                    groups[s.name] = []
                    order.append(s.name)
                groups[s.name].append(s)
            return [(n, groups[n]) for n in order]

        def fmt_t(sec: float) -> str:
            if sec >= 1.0:
                return f"{sec:.3f} s"
            if sec >= 1e-3:
                return f"{sec * 1e3:.3f} ms"
            return f"{sec * 1e6:.1f} us"

        def rec(spans: List[Span], depth: int, parent_total: Optional[float]) -> None:
            for name, group in merge(spans):
                total = sum(s.duration_s for s in group)
                frac = (total / parent_total) if parent_total else None
                if frac is not None and frac < min_frac:
                    continue
                pct = f"{frac * 100.0:8.1f}%" if frac is not None else " " * 9
                label = "  " * depth + name
                lines.append(f"{label:<44} {fmt_t(total):>12} {len(group):>6} {pct}")
                rec([c for s in group for c in s.children], depth + 1, total)

        rec(self.roots(), 0, None)
        return "\n".join(lines)


# -- module-level active tracer ---------------------------------------------
#
# A plain module global (not a contextvar): the async serving layer hands
# work to a background drain thread, which must inherit the tracer the
# client installed.  ``use_tracer`` is therefore process-wide; nested use
# restores the previous tracer on exit.

_ACTIVE: Optional[Tracer] = None
_ACTIVE_LOCK = threading.Lock()


class use_tracer:
    """Install ``tracer`` as the process-wide active tracer for a ``with`` block."""

    def __init__(self, tracer: Optional[Tracer]):
        self.tracer = tracer
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Optional[Tracer]:
        global _ACTIVE
        with _ACTIVE_LOCK:
            self._prev = _ACTIVE
            _ACTIVE = self.tracer
        return self.tracer

    def __exit__(self, *exc: Any) -> bool:
        global _ACTIVE
        with _ACTIVE_LOCK:
            _ACTIVE = self._prev
        return False


def get_tracer() -> Optional[Tracer]:
    """The currently active tracer, or None."""
    return _ACTIVE


def span(name: str, **attrs: Any):
    """Open a span on the active tracer; no-op (and allocation-free) without one."""
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return t.span(name, **attrs)


def record(name: str, t0: float, t1: float, **attrs: Any) -> None:
    """Retroactive root span on the active tracer (timestamps from ``tracer.now()``)."""
    t = _ACTIVE
    if t is not None:
        t.record(name, t0, t1, **attrs)
