from .adamw import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    apply_updates,
    global_norm,
    init,
    opt_state_pspecs,
    schedule,
    zero1_pspecs,
)
from . import compress  # noqa: F401
