"""AdamW with cosine schedule, global-norm clipping and optional ZeRO-1.

Self-contained (no optax dependency).  State is a pytree mirroring params
(m, v) plus a step counter.  ``zero1_pspecs`` extends the parameter
PartitionSpecs so optimizer moments are additionally sharded along the
data axis where divisible -- the ZeRO-1 trick: pjit then all-gathers
updated params once per step instead of replicating moments.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # ZeRO-style master weights: params live in bf16, the f32 master copy
    # lives in the optimizer state (shard it with zero1 over the data axis;
    # pjit all-gathers the bf16 params once per step)
    master_weights: bool = False


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict
    master: dict | None = None


def init(params, master_weights: bool = False) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    master = (
        jax.tree.map(lambda p: p.astype(jnp.float32), params)
        if master_weights
        else None
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros,
                      master=master)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    cfg: AdamWConfig, params, grads, state: AdamWState
):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.betas

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    src = state.master if (cfg.master_weights and state.master is not None) \
        else params
    flat_p, treedef = jax.tree.flatten(src)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_src = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    if cfg.master_weights and state.master is not None:
        # master stays f32 (sharded); distributed params refresh in bf16
        new_master = new_src
        new_p = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), new_master, params
        )
        new_state = AdamWState(step=step, m=new_m, v=new_v, master=new_master)
    else:
        new_p = new_src
        new_state = AdamWState(step=step, m=new_m, v=new_v, master=state.master)
    return (
        new_p,
        new_state,
        {"grad_norm": gnorm, "lr": lr},
    )


# ---------------------------------------------------------------------------
# ZeRO-1: shard optimizer moments over the data axis where possible
# ---------------------------------------------------------------------------


def zero1_pspecs(param_pspecs, params, mesh) -> dict:
    """Moment PartitionSpecs: param spec + data-axis sharding on the first
    dimension that is unsharded and divisible by the data-axis size."""
    data = mesh.shape.get("data", 1)

    def one(spec: P, p):
        if data <= 1:
            return spec
        used = set()
        for e in spec:
            if isinstance(e, tuple):
                used.update(e)
            elif e is not None:
                used.add(e)
        if "data" in used:  # e.g. FSDP already shards this leaf over data
            return spec
        entries = list(spec) + [None] * (p.ndim - len(spec))
        for i, (e, dim) in enumerate(zip(entries, p.shape)):
            if e is None and dim % data == 0:
                entries[i] = "data"
                return P(*entries)
        return spec

    return jax.tree.map(one, param_pspecs, params)


def opt_state_pspecs(param_pspecs, params, mesh, zero1: bool = False,
                     master_weights: bool = False):
    mspec = zero1_pspecs(param_pspecs, params, mesh) if zero1 else param_pspecs
    return AdamWState(
        step=P(), m=mspec, v=mspec,
        master=mspec if master_weights else None,
    )
