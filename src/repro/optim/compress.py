"""Int8 gradient compression with error feedback (cross-pod traffic trick).

On a >=2-pod mesh the data-parallel gradient all-reduce crosses the slow
inter-pod links; int8 quantization cuts that traffic 4x (vs f32 moments)
at no convergence cost when the quantization error is fed back into the
next step (Seide et al. / 1-bit SGD lineage).

``compress(g, err)`` returns (q, scale, new_err) where q is int8 and
``decompress`` reconstructs g_hat = q * scale.  In the training step the
pair wraps the cross-pod reduction:

    g_local        -> psum within pod (f32, fast ICI)
    compress       -> int8 + scale
    psum(pod axis) -> emulated by pjit on the quantized tensor
    decompress     -> g_hat; err' = g - g_hat  carried in opt state

The repo applies it inside ``train/loop.py`` when ``grad_compress=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array):
    """Quantize (g + err) to int8 with a per-tensor scale."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    g_hat = q.astype(jnp.float32) * scale
    new_err = g32 - g_hat
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err_tree):
    """Apply error-feedback int8 roundtrip to every leaf; returns
    (g_hat_tree, new_err_tree).  The int8 tensor is what would cross the
    pod axis; the roundtrip is numerically identical to a real int8
    all-reduce with deterministic summation order."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    outs = []
    errs = []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        outs.append(decompress(q, s))
        errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(errs)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
