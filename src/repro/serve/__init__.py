from .engine import Request, ServeEngine  # noqa: F401
from .solver_engine import (  # noqa: F401
    SolveOutcome,
    SolveRequest,
    SolverEngine,
    matrix_fingerprint,
)
