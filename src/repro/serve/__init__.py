from .engine import Request, ServeEngine  # noqa: F401
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .service import (  # noqa: F401
    AsyncSolverService,
    Cancelled,
    QueueFull,
    SolveCancelled,
    SolveFuture,
    default_class_overrides,
)
from .solver_engine import (  # noqa: F401
    SolveOutcome,
    SolveRequest,
    SolverEngine,
    band_dominance,
    matrix_fingerprint,
)
