"""Batched decode serving engine with continuous batching (slot refill).

A fixed number of batch slots share one jitted decode step; finished
requests free their slot, which is refilled from the queue without
recompiling (state is carried per-slot).  Prefill is teacher-forced
through ``decode_step`` token by token for cache-consistency (a dedicated
chunked-prefill path is a future optimization; the 32k-prefill dry-run
exercises the forward pass directly).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_family
from repro.models.api import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.fam = get_family(cfg)
        self.slots = slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = self.fam.init_cache(cfg, slots, max_len)
        self._step = jax.jit(
            lambda p, c, t: self.fam.decode_step(self.cfg, p, c, t)
        )
        self.tokens = np.zeros((slots, 1), np.int32)
        self._pending_prefill: List[deque] = [deque() for _ in range(slots)]

    def submit(self, req: Request):
        self.queue.append(req)

    def _refill(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self._pending_prefill[s] = deque(req.prompt)
                self.tokens[s, 0] = self._pending_prefill[s].popleft()

    def step(self):
        """One engine tick: advances every active slot by one token."""
        self._refill()
        if all(a is None for a in self.active):
            return False
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self.tokens)
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if self._pending_prefill[s]:
                # still prefilling: feed the next prompt token, ignore sample
                self.tokens[s, 0] = self._pending_prefill[s].popleft()
                continue
            req.out.append(int(nxt[s]))
            self.tokens[s, 0] = int(nxt[s])
            if len(req.out) >= req.max_new_tokens:
                req.done = True
                self.active[s] = None
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
