"""Serving metrics: thread-safe counters / gauges / histograms.

The observability layer for :class:`repro.serve.service.AsyncSolverService`
(and anything else in ``serve/``): a tiny prometheus-shaped registry --
monotonic :class:`Counter`, point-in-time :class:`Gauge`, and a
fixed-bucket :class:`Histogram` with quantile estimates -- that snapshots
to a plain dict so a serving benchmark can dump it straight into a
``BENCH_*.json`` trajectory row (:meth:`benchmarks.common.Report.write_json`).

Every instrument takes its own lock on update, so the drain thread, any
number of submitting client threads, and a scraping thread can all touch
the registry concurrently.  Updates are O(1) and allocation-free on the
hot path (histograms pre-size their bucket counts).
"""

from __future__ import annotations

import bisect
import re
import threading
from typing import Dict, Optional, Sequence, Tuple

# Default histogram bounds: latency-ish seconds spanning us..minutes, also
# serviceable for small counts (queue depth, batch occupancy percentages).
DEFAULT_BOUNDS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    """Monotonically increasing count (requests, misses, evictions...)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> float:
        return self.value


class Gauge:
    """Point-in-time level (queue depth now, cached factorizations now)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    def set_max(self, v: float) -> None:
        """Raise the gauge to ``v`` if higher (watermark semantics)."""
        v = float(v)
        with self._lock:
            if v > self._value:
                self._value = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bound histogram with count/sum/min/max and quantile estimates.

    ``bounds`` are the inclusive upper edges of the first ``len(bounds)``
    buckets; one overflow bucket catches the rest.  Quantiles are read
    from the cumulative bucket counts (the value reported is the upper
    edge of the bucket the quantile falls in -- the usual prometheus-style
    estimate), so they are conservative but lock-cheap.
    """

    def __init__(self, name: str, bounds: Sequence[float] = DEFAULT_BOUNDS):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name} needs sorted, non-empty bounds")
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Upper-edge estimate of quantile ``q`` in [0, 1].

        Edge behavior is exact, not bucket-interpolated: an *empty*
        histogram returns NaN for every ``q`` (there is no observation to
        estimate from); ``q=0`` returns the observed minimum and ``q=1``
        the observed maximum, since the tracked min/max are exact while
        bucket edges would only bound them.  Interior quantiles report
        the upper edge of the bucket the rank falls in (the conservative
        prometheus-style estimate).
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            if self._count == 0:
                return float("nan")
            if q == 0.0:
                return self._min
            if q == 1.0:
                return self._max
            rank = q * self._count
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= rank and c:
                    if i < len(self.bounds):
                        return self.bounds[i]
                    return self._max  # overflow bucket: best bound we have
            return self._max

    def _exposition_data(self) -> tuple:
        """(bounds, per-bucket counts, count, sum) under one lock hold."""
        with self._lock:
            return self.bounds, list(self._counts), self._count, self._sum

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self._count, self._sum
            counts = list(self._counts)
            vmin, vmax = self._min, self._max
        snap = {
            "count": count,
            "sum": round(total, 9),
            "mean": round(total / count, 9) if count else float("nan"),
            "min": vmin if count else float("nan"),
            "max": vmax if count else float("nan"),
            "buckets": {
                ("+inf" if i == len(self.bounds) else repr(self.bounds[i])): c
                for i, c in enumerate(counts)
                if c
            },
        }
        for q in (0.5, 0.9, 0.99):
            snap[f"p{int(q * 100)}"] = self.quantile(q)
        return snap


class MetricsRegistry:
    """Get-or-create registry of named instruments; snapshots to a dict.

    One registry per service.  ``counter``/``gauge``/``histogram`` are
    idempotent per name (re-registering with different bounds raises), so
    hot-path call sites can look instruments up by name without caching
    handles -- though caching the handle is cheaper still.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self._counters:
                self._check_free(name, self._counters)
                self._counters[name] = Counter(name)
            return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._check_free(name, self._gauges)
                self._gauges[name] = Gauge(name)
            return self._gauges[name]

    def histogram(
        self, name: str, bounds: Optional[Sequence[float]] = None
    ) -> Histogram:
        with self._lock:
            hist = self._histograms.get(name)
            if hist is None:
                self._check_free(name, self._histograms)
                hist = Histogram(name, bounds or DEFAULT_BOUNDS)
                self._histograms[name] = hist
            elif bounds is not None and tuple(bounds) != hist.bounds:
                raise ValueError(
                    f"histogram {name!r} already registered with different "
                    f"bounds"
                )
            return hist

    def _check_free(self, name: str, owner: dict) -> None:
        for family in (self._counters, self._gauges, self._histograms):
            if family is not owner and name in family:
                raise ValueError(
                    f"metric name {name!r} already used by another type"
                )

    def snapshot(self) -> dict:
        """One coherent-enough dict of every instrument (JSON-ready)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.snapshot() for n, c in counters.items()},
            "gauges": {n: g.snapshot() for n, g in gauges.items()},
            "histograms": {n: h.snapshot() for n, h in histograms.items()},
        }

    def to_prometheus(self, prefix: str = "") -> str:
        """Every instrument in the Prometheus text exposition format.

        Counters carry the conventional ``_total`` suffix; histograms emit
        *cumulative* ``_bucket{le="..."}`` series (including the ``+Inf``
        catch-all) plus ``_sum`` / ``_count``.  Names are sanitized to the
        prometheus charset.  Serve the result over HTTP with content type
        ``text/plain; version=0.0.4`` and it scrapes directly.
        """
        lines = []
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        for n, c in sorted(counters.items()):
            pn = _prom_name(prefix + n)
            if not pn.endswith("_total"):
                pn += "_total"
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {_prom_value(c.value)}")
        for n, g in sorted(gauges.items()):
            pn = _prom_name(prefix + n)
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_prom_value(g.value)}")
        for n, h in sorted(histograms.items()):
            pn = _prom_name(prefix + n)
            bounds, counts, count, total = h._exposition_data()
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for b, c in zip(bounds, counts):
                cum += c
                lines.append(f'{pn}_bucket{{le="{_prom_value(b)}"}} {cum}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {count}')
            lines.append(f"{pn}_sum {_prom_value(total)}")
            lines.append(f"{pn}_count {count}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    n = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    return f"_{n}" if n and n[0].isdigit() else n


def _prom_value(v: float) -> str:
    return format(float(v), ".10g")
