"""Async multi-tenant solve service: futures, deadlines, priorities.

:class:`AsyncSolverService` turns the synchronous :class:`SolverEngine`
into a real serving subsystem, the "millions of users" path of the
ROADMAP.  Clients on any thread call :meth:`AsyncSolverService.submit`
and get a :class:`SolveFuture` back immediately; a background **drain
thread** forms device batches and resolves the futures.  The pieces:

* **Futures** -- ``submit()`` returns a :class:`SolveFuture`
  (``threading.Event``-backed): ``result(timeout)`` blocks for the
  outcome, ``done()``/``cancelled()`` poll, ``cancel()`` withdraws a
  not-yet-scheduled request.

* **Overlap** -- the expensive host-side request prep (band fingerprint,
  dominance estimate, bucket shape) runs on the *submitting* thread,
  outside every lock, while the drain thread's device solve is in
  flight.  Arrival work and device work overlap instead of serializing,
  which is where the async throughput win over sequential
  ``submit``+``run_until_drained`` comes from (arXiv:1906.04051 makes
  the same observation for Krylov throughput at cluster scale: host
  orchestration overlap dominates end-to-end solve rate).

* **Scheduling** -- requests carry ``priority`` (higher first) and
  ``deadline_s``.  The drain thread picks the scheduling class with the
  highest-priority pending request, tie-breaking by earliest deadline
  (EDF), and drains up to ``max_batch`` of its requests.  Requests whose
  deadline already passed are **shed** with a :class:`Cancelled` outcome
  instead of occupying batch slots.

* **Admission control** -- the pending set is bounded by ``queue_cap``:
  ``submit(block=False)`` raises :class:`QueueFull`, ``block=True``
  (default) applies backpressure by blocking the caller.  An LRU-thrash
  guard watches the engine's eviction rate and widens the bucket
  rounding ("exact" -> "pow2") when the factorization cache churns, so a
  long tail of one-off shapes stops evicting the working set.

* **Per-class options** -- each request is routed to a dominance class
  from its host-side d estimate (paper Eq. 2.11): ``d >= 1`` solves with
  the cheap truncated variant "C", ``d < 1`` with the exact reduced
  system "E" + log-depth BCR -- per-bucket options replacing the
  engine's single shared ``SaPOptions`` (the sub-structuring-as-
  preconditioner view of arXiv:2108.13162: route by spectral character,
  don't average over it).

* **Metrics** -- a :class:`repro.serve.metrics.MetricsRegistry` records
  queue depth, time-in-queue, batch occupancy, cache hits/misses,
  deadline misses, and solves/sec; ``snapshot()`` is JSON-ready and
  feeds the ``BENCH_serve.json`` trajectory row.  The misconvergence
  guard is observable too: ``misconverged_total`` counts solves whose
  iteration claimed convergence while the true residual failed the
  guard, ``escalations`` counts the exact-bucket re-solves the engine
  ran in response (see :class:`repro.serve.solver_engine.SolveOutcome`).
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import batched
from repro.core.sap import SaPOptions
from repro.obs.trace import get_tracer, span
from repro.serve.metrics import MetricsRegistry
from repro.serve.solver_engine import (
    SolveOutcome,
    SolveRequest,
    SolverEngine,
    band_dominance,
    matrix_fingerprint,
)

DOMINANT = "dom"  # d >= 1: spike truncation justified (variant "C")
NON_DOMINANT = "nondom"  # d < 1: exact reduced system required ("E")


class QueueFull(RuntimeError):
    """Admission control rejected a submit (queue at ``queue_cap``)."""


class SolveCancelled(RuntimeError):
    """Raised by :meth:`SolveFuture.result` when the request was shed."""

    def __init__(self, reason: str):
        super().__init__(f"solve cancelled: {reason}")
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class Cancelled:
    """Terminal non-solve outcome (deadline shed / client cancel / ...)."""

    reason: str  # "deadline" | "client" | "shutdown" | "error: ..."


class SolveFuture:
    """Handle for one in-flight solve; resolves exactly once.

    ``outcome(timeout)`` returns either a
    :class:`~repro.serve.solver_engine.SolveOutcome` or a
    :class:`Cancelled`; ``result(timeout)`` is the strict form that
    raises :class:`SolveCancelled` on shed/cancel (the
    ``concurrent.futures`` convention).
    """

    def __init__(self, rid: int):
        self.rid = rid
        self._event = threading.Event()
        self._outcome: SolveOutcome | Cancelled | None = None
        self._cancel_requested = False

    # -- client side --------------------------------------------------------

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return isinstance(self._outcome, Cancelled)

    def cancel(self) -> bool:
        """Request withdrawal; honored only if not yet scheduled.

        Best-effort: the drain thread drops cancel-requested tickets at
        scheduling time, but a request already inside a device batch
        completes normally.  Returns False only when the future already
        resolved non-cancelled; True means cancellation happened or may
        still happen.
        """
        self._cancel_requested = True
        return not self.done() or self.cancelled()

    def outcome(self, timeout: Optional[float] = None):
        """Block for the terminal outcome: SolveOutcome | Cancelled."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"solve future rid={self.rid} unresolved after {timeout}s"
            )
        return self._outcome

    def result(self, timeout: Optional[float] = None) -> SolveOutcome:
        out = self.outcome(timeout)
        if isinstance(out, Cancelled):
            raise SolveCancelled(out.reason)
        return out

    # -- service side -------------------------------------------------------

    def _resolve(self, outcome) -> None:
        if self._event.is_set():  # first resolution wins
            return
        self._outcome = outcome
        self._event.set()


@dataclasses.dataclass
class _Ticket:
    """A submitted request waiting in the service's scheduling queues."""

    rid: int
    band: np.ndarray
    b: np.ndarray
    fingerprint: str
    dclass: str
    bucket: Tuple[int, int, int]
    priority: int
    deadline: Optional[float]  # absolute time.monotonic(), None = none
    t_submit: float
    future: SolveFuture
    # submit timestamp on the active tracer's clock (0.0 when no tracer was
    # active): lets the drain thread emit a retroactive "serve.request" span
    # covering submit -> resolve.  Separate from t_submit because deadlines
    # use time.monotonic() while the tracer clock is time.perf_counter().
    t_trace: float = 0.0

    def sort_key(self):
        # higher priority first, then earliest deadline (EDF), then FIFO
        return (
            -self.priority,
            self.deadline if self.deadline is not None else float("inf"),
            self.rid,
        )


def default_class_overrides(base: SaPOptions) -> Dict[str, SaPOptions]:
    """The per-dominance-class options the service routes batches to."""
    return {
        DOMINANT: dataclasses.replace(base, variant="C"),
        NON_DOMINANT: dataclasses.replace(
            base, variant="E", reduced_solver="bcr"
        ),
    }


class AsyncSolverService:
    """Asynchronous multi-tenant front end over :class:`SolverEngine`.

    Parameters
    ----------
    opts            : base solver options; per-class overrides derive from
                      it (``class_overrides`` replaces them wholesale --
                      every override must keep the same ``p``).
    max_batch       : per-dispatch batch cap (one bucket per dispatch)
    cache_size      : engine LRU capacity (factorizations)
    rounding        : initial bucket rounding ("pow2" | "exact"); the
                      thrash guard may widen "exact" to "pow2" at runtime
    queue_cap       : max pending requests before admission control kicks in
    default_deadline_s : deadline applied when submit() passes none
    thrash_window   : evaluate the thrash guard every this-many solves
    thrash_ratio    : evictions/solve above which rounding widens
    class_overrides : per-dominance-class SaPOptions overrides
    metrics         : optional shared MetricsRegistry
    hist_bounds     : upper bucket edges for the latency-style histograms
                      (``time_in_queue_s``); None keeps
                      :data:`repro.serve.metrics.DEFAULT_BOUNDS`.  Settable
                      from :class:`repro.configs.sap_solver.SolverConfig`
                      (``hist_bounds``), so deployments with tight or loose
                      latency envelopes get resolution where their traffic
                      actually lands.
    start           : spawn the drain thread immediately (tests pass
                      False and call ``drain_once()`` deterministically)
    """

    def __init__(
        self,
        opts: Optional[SaPOptions] = None,
        *,
        max_batch: int = 32,
        cache_size: int = 128,
        rounding: str = "pow2",
        queue_cap: int = 256,
        default_deadline_s: Optional[float] = None,
        thrash_window: int = 32,
        thrash_ratio: float = 0.5,
        class_overrides: Optional[Dict[str, SaPOptions]] = None,
        metrics: Optional[MetricsRegistry] = None,
        hist_bounds: Optional[Tuple[float, ...]] = None,
        cost_accounting: bool = False,
        start: bool = True,
    ):
        base = opts or SaPOptions()
        self.engine = SolverEngine(
            base, max_batch=max_batch, cache_size=cache_size,
            rounding=rounding, cost_accounting=cost_accounting,
        )
        self.max_batch = max_batch
        self.rounding = rounding
        self.queue_cap = queue_cap
        self.default_deadline_s = default_deadline_s
        self.thrash_window = thrash_window
        self.thrash_ratio = thrash_ratio
        self.class_overrides = (
            dict(class_overrides)
            if class_overrides is not None
            else default_class_overrides(base)
        )
        for cls, o in self.class_overrides.items():
            if o.p != base.p:
                raise ValueError(
                    f"class override {cls!r} changes p ({o.p} != {base.p}); "
                    "buckets are keyed by the base partition count"
                )
        self.metrics = metrics or MetricsRegistry()
        m = self.metrics
        occupancy = tuple(i / 16 for i in range(1, 17))
        depth = tuple(float(x) for x in
                      (0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024))
        self._m_submitted = m.counter("submitted")
        self._m_solved = m.counter("solved")
        self._m_shed = m.counter("deadline_misses")
        self._m_cancelled = m.counter("client_cancels")
        self._m_rejected = m.counter("queue_rejections")
        self._m_widened = m.counter("rounding_widenings")
        self._m_hits = m.counter("cache_hits")
        self._m_misses = m.counter("cache_misses")
        # misconvergence guard: solves whose Krylov iteration claimed
        # convergence but whose TRUE residual failed the guard, and the
        # exact-bucket escalation re-solves the engine ran in response
        self._m_misconverged = m.counter("misconverged_total")
        self._m_escalations = m.counter("escalations")
        # compile churn + memory pressure (repro.obs.cost telemetry): the
        # counters are synced by delta from the process-wide CompileLog at
        # the end of every drain, so the exposition names come out as the
        # conventional recompiles_total / compile_seconds_total.
        self._m_recompiles = m.counter("recompiles")
        self._m_compile_s = m.counter("compile_seconds")
        self._m_peak_bytes = m.gauge("peak_device_bytes")
        self._m_depth = m.histogram("queue_depth", depth)
        self._m_wait = m.histogram("time_in_queue_s", hist_bounds)
        self._m_occ = m.histogram("batch_occupancy", occupancy)
        self._m_pending = m.gauge("pending_now")
        self._compiles_seen = self.engine._compiles0

        # scheduling state: (bucket, dclass) -> [tickets]; one condition
        # variable serves submitters (backpressure) and the drain thread.
        self._cv = threading.Condition()
        self._pending: Dict[Tuple, List[_Ticket]] = {}
        self._n_pending = 0
        self._rid = itertools.count()
        self._closing = False
        self._t_start = time.monotonic()
        self._last_thrash_check = (0, 0)  # (evictions, solved) at last check
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._drain_loop, name="sap-serve-drain", daemon=True
        )
        self._thread.start()

    def close(self, drain: bool = True, timeout: Optional[float] = 60.0):
        """Stop the service.  ``drain=True`` finishes queued work first;
        ``drain=False`` sheds everything pending as Cancelled("shutdown")."""
        with self._cv:
            self._closing = True
            if not drain:
                for t in self._drop_all():
                    t.future._resolve(Cancelled("shutdown"))
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        # whatever the drain thread left behind (e.g. join timeout)
        with self._cv:
            for t in self._drop_all():
                t.future._resolve(Cancelled("shutdown"))

    def __enter__(self) -> "AsyncSolverService":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=not any(exc))

    # -- submission (client threads) ----------------------------------------

    def submit(
        self,
        band,
        b,
        *,
        priority: int = 0,
        deadline_s: Optional[float] = None,
        block: bool = True,
        timeout: Optional[float] = None,
    ) -> SolveFuture:
        """Enqueue one banded system; returns immediately with a future.

        Host-side prep (fingerprint hash, dominance estimate, bucket
        shape) runs here on the *caller's* thread, outside every lock --
        submission work overlaps the drain thread's in-flight device
        solves.  ``block`` selects the backpressure behavior when the
        queue sits at ``queue_cap``: block (optionally up to ``timeout``
        seconds) or raise :class:`QueueFull` right away.
        """
        if self._closing:
            raise RuntimeError("service is closed")
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        band = np.asarray(band)
        b = np.asarray(b)
        fp = matrix_fingerprint(band)
        d = band_dominance(band)
        dclass = DOMINANT if d >= 1.0 else NON_DOMINANT
        n, k = band.shape[0], (band.shape[1] - 1) // 2
        now = time.monotonic()
        tr = get_tracer()
        t_trace = tr.now() if tr else 0.0
        fut = SolveFuture(next(self._rid))
        with self._cv:
            while self._n_pending >= self.queue_cap and not self._closing:
                if not block:
                    self._m_rejected.inc()
                    raise QueueFull(
                        f"{self._n_pending} pending >= cap {self.queue_cap}"
                    )
                if not self._cv.wait(timeout):
                    self._m_rejected.inc()
                    raise QueueFull(
                        f"no queue slot within {timeout}s "
                        f"(cap {self.queue_cap})"
                    )
            if self._closing:
                raise RuntimeError("service is closed")
            # bucket under the lock: the thrash guard flips self.rounding
            bucket = batched.bucket_shape(n, k, self.engine.opts.p,
                                          self.rounding)
            ticket = _Ticket(
                rid=fut.rid, band=band, b=b, fingerprint=fp, dclass=dclass,
                bucket=bucket, priority=priority,
                deadline=(now + deadline_s) if deadline_s is not None
                else None,
                t_submit=now, future=fut, t_trace=t_trace,
            )
            self._pending.setdefault((bucket, dclass), []).append(ticket)
            self._n_pending += 1
            self._m_submitted.inc()
            self._m_depth.observe(self._n_pending)
            self._m_pending.set(self._n_pending)
            self._cv.notify_all()
        return fut

    # -- scheduling + drain (drain thread) ----------------------------------

    def _drop_all(self) -> List[_Ticket]:
        """Clear every queue (caller holds the lock); returns the tickets."""
        dropped = [t for ts in self._pending.values() for t in ts]
        self._pending.clear()
        self._n_pending = 0
        self._m_pending.set(0)
        self._cv.notify_all()
        return dropped

    def _shed_locked(self, now: float) -> List[_Ticket]:
        """Remove expired / client-cancelled tickets (caller holds lock)."""
        shed: List[Tuple[_Ticket, str]] = []
        for key in list(self._pending):
            keep = []
            for t in self._pending[key]:
                if t.future._cancel_requested:
                    shed.append((t, "client"))
                elif t.deadline is not None and t.deadline < now:
                    shed.append((t, "deadline"))
                else:
                    keep.append(t)
            if keep:
                self._pending[key] = keep
            else:
                del self._pending[key]
        if shed:
            self._n_pending -= len(shed)
            self._m_pending.set(self._n_pending)
            self._cv.notify_all()  # slots freed: wake blocked submitters
        for t, reason in shed:
            (self._m_shed if reason == "deadline"
             else self._m_cancelled).inc()
            t.future._resolve(Cancelled(reason))
        return [t for t, _ in shed]

    def _select_locked(self) -> Optional[Tuple[Tuple, List[_Ticket]]]:
        """Pick the next batch (caller holds the lock).

        Scheduling class = (bucket, dominance class).  The class owning
        the globally best ticket -- highest priority, then earliest
        deadline -- wins the dispatch; up to ``max_batch`` of its tickets
        go out in the same order.  Starvation-resistant in the useful
        sense: a class only waits while strictly better work exists.
        """
        best_key, best = None, None
        for key, tickets in self._pending.items():
            head = min(tickets, key=_Ticket.sort_key)
            if best is None or head.sort_key() < best.sort_key():
                best_key, best = key, head
        if best_key is None:
            return None
        tickets = sorted(self._pending[best_key], key=_Ticket.sort_key)
        batch, rest = tickets[: self.max_batch], tickets[self.max_batch:]
        if rest:
            self._pending[best_key] = rest
        else:
            del self._pending[best_key]
        self._n_pending -= len(batch)
        self._m_pending.set(self._n_pending)
        self._cv.notify_all()
        return best_key, batch

    def drain_once(self) -> int:
        """Shed expired work, dispatch at most one batch; returns the
        number of futures resolved.  The drain loop's body -- public so
        tests (and single-threaded callers) can run the scheduler
        deterministically without a background thread."""
        with self._cv:
            self._shed_locked(time.monotonic())
            picked = self._select_locked()
        if picked is None:
            return 0
        (bucket, dclass), tickets = picked
        opts = self.class_overrides[dclass]
        reqs = [
            SolveRequest(rid=t.rid, band=t.band, b=t.b,
                         fingerprint=t.fingerprint)
            for t in tickets
        ]
        try:
            # the device batch: runs outside the condition variable, so
            # submitters keep hashing/enqueueing while this is in flight
            with span(
                "serve.dispatch",
                bucket=f"{bucket[0]}x{bucket[1]}",
                dclass=dclass,
                batch=len(tickets),
            ):
                self.engine.solve_prepared(reqs, bucket, opts=opts)
        except Exception as e:  # resolve, never hang the futures
            for t in tickets:
                t.future._resolve(Cancelled(f"error: {e!r}"))
            return len(tickets)
        now = time.monotonic()
        tr = get_tracer()
        hits = 0
        mis = esc = 0
        for t, r in zip(tickets, reqs):
            hits += bool(r.result.cache_hit)
            # an escalated outcome replaced a misconverged first pass, so
            # it counts as a misconvergence even if the re-solve cured it
            esc += bool(r.result.escalated)
            mis += bool(r.result.escalated or r.result.misconverged)
            self._m_wait.observe(now - t.t_submit)
            t.future._resolve(r.result)
            if tr is not None and t.t_trace > 0.0:
                # retroactive per-request span: queue -> dispatch -> resolve
                tr.record(
                    "serve.request",
                    t.t_trace,
                    tr.now(),
                    rid=t.rid,
                    dclass=t.dclass,
                    bucket=f"{t.bucket[0]}x{t.bucket[1]}",
                    queue_s=round(now - t.t_submit, 6),
                    cache_hit=bool(r.result.cache_hit),
                )
        self._m_solved.inc(len(tickets))
        self._m_hits.inc(hits)
        self._m_misses.inc(len(tickets) - hits)
        if mis:
            self._m_misconverged.inc(mis)
        if esc:
            self._m_escalations.inc(esc)
        self._m_occ.observe(len(tickets) / self.max_batch)
        self._check_thrash()
        self._sync_cost_metrics()
        return len(tickets)

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                while not self._n_pending and not self._closing:
                    self._cv.wait()
                if self._closing and not self._n_pending:
                    return
            self.drain_once()

    def _check_thrash(self) -> None:
        """Widen bucket rounding when the factorization LRU churns.

        Under "exact" rounding a spread of one-off (N, K) shapes makes
        every shape its own bucket; if the eviction rate over the last
        ``thrash_window`` solves exceeds ``thrash_ratio``, collapse the
        shape space by switching to "pow2" rounding (logarithmically many
        buckets), which lets near-miss shapes share cache entries instead
        of evicting each other.  Already-queued tickets keep their old
        bucket; only new arrivals see the widened rounding.
        """
        stats = self.engine.stats_snapshot()
        ev, solved = stats["evictions"], stats["solved"]
        ev0, solved0 = self._last_thrash_check
        if solved - solved0 < self.thrash_window:
            return
        rate = (ev - ev0) / max(solved - solved0, 1)
        self._last_thrash_check = (ev, solved)
        if rate > self.thrash_ratio and self.rounding == "exact":
            with self._cv:
                if self.rounding == "exact":
                    self.rounding = "pow2"
                    self._m_widened.inc()

    def _sync_cost_metrics(self) -> None:
        """Fold compile-telemetry deltas and the engine's device-memory
        watermark into the registry (end of every drain).  Counter deltas
        come from the process-wide :data:`repro.obs.cost.COMPILES` log, so
        the service sees compiles wherever they happen -- the engine's AOT
        factor cache, the cost layer, or plain jit cache misses."""
        from repro.obs.cost import COMPILES

        count, seconds = COMPILES.totals()
        c0, s0 = self._compiles_seen
        if count > c0:
            self._m_recompiles.inc(count - c0)
        if seconds > s0:
            self._m_compile_s.inc(seconds - s0)
        self._compiles_seen = (count, seconds)
        self._m_peak_bytes.set_max(
            self.engine.stats_snapshot()["peak_device_bytes"]
        )

    # -- observability ------------------------------------------------------

    @property
    def pending(self) -> int:
        with self._cv:
            return self._n_pending

    def render(self) -> str:
        """Prometheus text exposition of the service's metrics registry.

        Serve over HTTP with content type ``text/plain; version=0.0.4``
        and a stock Prometheus scraper ingests it as-is.
        """
        return self.metrics.to_prometheus()

    def snapshot(self) -> dict:
        """JSON-ready view: service metrics + engine counters + derived."""
        snap = self.metrics.snapshot()
        snap["engine"] = self.engine.stats_snapshot()
        elapsed = max(time.monotonic() - self._t_start, 1e-9)
        solved = snap["counters"].get("solved", 0.0)
        served = solved + snap["counters"].get("deadline_misses", 0.0)
        hits = snap["counters"].get("cache_hits", 0.0)
        misses = snap["counters"].get("cache_misses", 0.0)
        snap["derived"] = {
            "uptime_s": round(elapsed, 6),
            "solves_per_second": round(solved / elapsed, 3),
            "requests_per_second": round(served / elapsed, 3),
            "cache_hit_rate": round(hits / (hits + misses), 4)
            if hits + misses else 0.0,
            "rounding": self.rounding,
        }
        return snap
