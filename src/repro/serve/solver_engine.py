"""Batched linear-solver serving engine: bucketed fleets, cached factors.

The solver counterpart of :class:`repro.serve.engine.ServeEngine`: clients
``submit()`` independent banded systems (one matrix + one RHS each) and
the engine turns the pending queue into *batched* device work:

1. **Bucketing** -- each request's ``(N, K)`` rounds up to a compiled
   shape bucket (:func:`repro.core.batched.bucket_shape`); systems are
   identity-padded into the bucket so heterogeneous fleets share one
   executable without approximation.

2. **Factorization cache** -- factorizations are cached in an LRU keyed
   by a *matrix fingerprint* (content hash of the band bytes + the bucket
   shape).  Implicit time stepping re-solves against the same (or slowly
   refreshed) matrix every step: repeated fingerprints skip straight to
   the Krylov stage, paying factor-once economics across requests, not
   just across the RHS of one handle.

3. **Batched dispatch** -- every :meth:`SolverEngine.step` drains up to
   ``max_batch`` requests from ONE bucket, batch-factors the cache misses
   in a single vmapped pass (:func:`repro.core.batched.batch_factor`),
   stacks cached + fresh factorizations, and runs one ``solve_batch``.

Cache-hit and throughput counters live on :attr:`SolverEngine.stats`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Deque, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import batched
from repro.core.sap import SaPOptions


def matrix_fingerprint(band) -> str:
    """Content hash of a band-storage matrix (shape + dtype + bytes).

    Host-side and cheap relative to a factorization; two requests carry
    the same fingerprint iff their band arrays are bit-identical, which
    is exactly the implicit-time-stepping reuse pattern (the Jacobian is
    refreshed every few steps, not every solve).
    """
    a = np.ascontiguousarray(np.asarray(band))
    h = hashlib.blake2b(digest_size=16)
    h.update(str((a.shape, a.dtype.str)).encode())
    h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class SolveRequest:
    """One banded system A x = b submitted to the engine."""

    rid: int
    band: np.ndarray | jnp.ndarray  # (N, 2K+1) band storage
    b: np.ndarray | jnp.ndarray  # (N,) right-hand side
    fingerprint: Optional[str] = None  # filled by submit() if absent
    result: Optional["SolveOutcome"] = None

    @property
    def done(self) -> bool:
        return self.result is not None


@dataclasses.dataclass
class SolveOutcome:
    """Per-request result (device batch sliced back to the original N)."""

    x: np.ndarray
    iterations: float
    resnorm: float
    converged: bool
    cache_hit: bool
    bucket: Tuple[int, int, int]


class SolverEngine:
    """Shape-bucketed, factorization-caching batched solve server.

    opts       : solver options shared by every request (p, variant, tol..)
    max_batch  : per-step batch-size cap (one bucket per step)
    cache_size : LRU capacity in cached factorizations
    rounding   : bucket rounding policy ("pow2" | "exact")
    """

    def __init__(
        self,
        opts: Optional[SaPOptions] = None,
        max_batch: int = 32,
        cache_size: int = 128,
        rounding: str = "pow2",
    ):
        self.opts = opts or SaPOptions()
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.rounding = rounding
        self.queue: Deque[SolveRequest] = deque()
        self._next_rid = 0
        # (fingerprint, bucket) -> single-system SaPFactorization slice
        self._cache: OrderedDict = OrderedDict()
        self.stats = {
            "submitted": 0,
            "solved": 0,
            "steps": 0,
            "cache_hits": 0,
            "cache_misses": 0,
            "factored_systems": 0,
            "evictions": 0,
            "solve_seconds": 0.0,
        }

    # -- submission ---------------------------------------------------------

    def submit(self, req: SolveRequest) -> int:
        if req.fingerprint is None:
            req.fingerprint = matrix_fingerprint(req.band)
        self.queue.append(req)
        self.stats["submitted"] += 1
        return req.rid

    def submit_system(self, band, b) -> int:
        """Convenience wrapper: wrap (band, b) in a request, return its rid."""
        rid = self._next_rid
        self._next_rid += 1
        self.submit(SolveRequest(rid=rid, band=band, b=b))
        return rid

    # -- cache --------------------------------------------------------------

    def _cache_get(self, key):
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
        return hit

    def _cache_put(self, key, value):
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1

    @property
    def cached_factorizations(self) -> int:
        return len(self._cache)

    # -- the engine tick ----------------------------------------------------

    def step(self) -> List[SolveRequest]:
        """One tick: solve up to ``max_batch`` requests of one bucket.

        Picks the bucket with the most pending requests (largest batch =
        best amortization), factors its cache misses in one vmapped pass,
        then runs one batched solve.  Returns the completed requests.
        """
        if not self.queue:
            return []
        t0 = time.perf_counter()

        shapes = [
            (np.shape(r.band)[0], (np.shape(r.band)[1] - 1) // 2)
            for r in self.queue
        ]
        buckets = batched.bucket_by_shape(shapes, self.opts.p, self.rounding)
        bucket, idxs = max(buckets.items(), key=lambda kv: len(kv[1]))
        idxs = set(idxs[: self.max_batch])
        batch = [r for i, r in enumerate(self.queue) if i in idxs]
        self.queue = deque(r for i, r in enumerate(self.queue) if i not in idxs)

        nb, kb, _ = bucket
        # 1) factor the cache misses in ONE vmapped pass.  A batch may
        #    repeat a fingerprint (same Jacobian, many RHS requests): each
        #    distinct matrix is factored once, duplicates count as hits.
        #    ``step_facs`` pins this step's factorizations locally -- the
        #    LRU may evict mid-step (cache_size < distinct matrices in
        #    one batch) without pulling them out from under the solve.
        step_facs: dict = {}
        miss_fps: List[str] = []
        miss_reqs: List[SolveRequest] = []
        is_hit: List[bool] = []
        for r in batch:
            cached = self._cache_get((r.fingerprint, bucket))
            if cached is not None:
                step_facs[r.fingerprint] = cached
                is_hit.append(True)
            elif r.fingerprint in miss_fps:
                is_hit.append(True)
            else:
                is_hit.append(False)
                miss_fps.append(r.fingerprint)
                miss_reqs.append(r)
        if miss_reqs:
            bpl = batched.batch_plan(
                [r.band for r in miss_reqs], self.opts, rounding=self.rounding
            )
            assert (bpl.n, bpl.k) == (nb, kb), "bucketing is shape-consistent"
            bfac = batched.batch_factor(bpl)
            # Sticky "auto" resolution: cached and future factorizations
            # must share one pytree structure to stack into one batch, so
            # the first factored batch pins the resolved variant.
            if self.opts.variant == "auto":
                self.opts = dataclasses.replace(
                    self.opts, variant=bfac.variant
                )
            for j, fp in enumerate(miss_fps):
                fac = batched.index_factorization(bfac, j)
                step_facs[fp] = fac
                self._cache_put((fp, bucket), fac)
            self.stats["factored_systems"] += len(miss_reqs)
        self.stats["cache_hits"] += sum(is_hit)
        self.stats["cache_misses"] += len(is_hit) - sum(is_hit)

        # 2) one batched solve over cached + fresh factorizations
        facs = [step_facs[r.fingerprint] for r in batch]
        orig_ns = [np.shape(r.band)[0] for r in batch]
        bfac = batched.stack_factorizations(facs, orig_ns)
        bmat = jnp.stack(
            [batched.pad_rhs_to(jnp.asarray(r.b), nb) for r in batch]
        )
        res = bfac.solve_batch(bmat)
        xs = batched.unpad_solution(res.x, orig_ns)
        iters = np.asarray(res.iterations)
        rnorm = np.asarray(res.resnorm)
        conv = np.asarray(res.converged)
        for i, r in enumerate(batch):
            r.result = SolveOutcome(
                x=xs[i],
                iterations=float(iters[i]),
                resnorm=float(rnorm[i]),
                converged=bool(conv[i]),
                cache_hit=is_hit[i],
                bucket=bucket,
            )
        self.stats["solved"] += len(batch)
        self.stats["steps"] += 1
        self.stats["solve_seconds"] += time.perf_counter() - t0
        return batch

    def run_until_drained(self, max_steps: int = 10_000) -> List[SolveRequest]:
        done: List[SolveRequest] = []
        steps = 0
        while self.queue and steps < max_steps:
            done.extend(self.step())
            steps += 1
        return done

    # -- derived stats ------------------------------------------------------

    @property
    def cache_hit_rate(self) -> float:
        tot = self.stats["cache_hits"] + self.stats["cache_misses"]
        return self.stats["cache_hits"] / tot if tot else 0.0

    @property
    def systems_per_second(self) -> float:
        sec = self.stats["solve_seconds"]
        return self.stats["solved"] / sec if sec > 0 else 0.0
