"""Batched linear-solver serving engine: bucketed fleets, cached factors.

The solver counterpart of :class:`repro.serve.engine.ServeEngine`: clients
``submit()`` independent banded systems (one matrix + one RHS each) and
the engine turns the pending queue into *batched* device work:

1. **Bucketing** -- each request's ``(N, K)`` rounds up to a compiled
   shape bucket (:func:`repro.core.batched.bucket_shape`); systems are
   identity-padded into the bucket so heterogeneous fleets share one
   executable without approximation.

2. **Factorization cache** -- factorizations are cached in an LRU keyed
   by a *matrix fingerprint* (content hash of the band bytes + the bucket
   shape + the factor-relevant options).  Implicit time stepping
   re-solves against the same (or slowly refreshed) matrix every step:
   repeated fingerprints skip straight to the Krylov stage, paying
   factor-once economics across requests, not just across the RHS of one
   handle.

3. **Batched dispatch** -- every :meth:`SolverEngine.step` drains up to
   ``max_batch`` requests from ONE bucket, batch-factors the cache misses
   in a single vmapped pass (:func:`repro.core.batched.batch_factor`),
   stacks cached + fresh factorizations, and runs one ``solve_batch``.

The engine is **thread-safe**: the pending queue, the LRU cache, and the
``stats`` dict each sit behind a lock, so an async drain thread
(:class:`repro.serve.service.AsyncSolverService`) can run
:meth:`solve_prepared` while client threads keep ``submit()``-ing.  Device
solves run *outside* the locks -- host-side bookkeeping of incoming
requests overlaps in-flight device work.

Cache-hit and throughput counters live on :attr:`SolverEngine.stats`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
import warnings
from collections import OrderedDict, deque
from typing import Deque, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched
from repro.core.sap import SaPOptions, resolve_variant
from repro.obs import cost as obs_cost
from repro.obs.trace import span


def matrix_fingerprint(band) -> str:
    """Content hash of a band-storage matrix (shape + dtype + bytes).

    Host-side and cheap relative to a factorization; two requests carry
    the same fingerprint iff their band arrays are bit-identical, which
    is exactly the implicit-time-stepping reuse pattern (the Jacobian is
    refreshed every few steps, not every solve).
    """
    a = np.ascontiguousarray(np.asarray(band))
    h = hashlib.blake2b(digest_size=16)
    h.update(str((a.shape, a.dtype.str)).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def band_dominance(band) -> float:
    """Host-side degree of diagonal dominance (paper Eq. 2.11).

    The numpy twin of :func:`repro.core.banded.diag_dominance_factor`:
    ``min_i |a_ii| / sum_{j!=i} |a_ij|`` with zero-off-diagonal rows
    dropping out of the minimum.  Runs on the submit path (no device
    round trip) to route requests to a dominance class before any
    factorization happens.
    """
    a = np.abs(np.asarray(band, dtype=np.float64))
    k = (a.shape[1] - 1) // 2
    diag = a[:, k]
    off = a.sum(axis=1) - diag
    ratio = np.where(off > 0, diag / np.where(off > 0, off, 1.0), np.inf)
    return float(ratio.min()) if ratio.size else float("inf")


@dataclasses.dataclass
class SolveRequest:
    """One banded system A x = b submitted to the engine."""

    rid: int
    band: np.ndarray | jnp.ndarray  # (N, 2K+1) band storage
    b: np.ndarray | jnp.ndarray  # (N,) right-hand side
    fingerprint: Optional[str] = None  # filled by submit() if absent
    result: Optional["SolveOutcome"] = None

    @property
    def done(self) -> bool:
        """True once a SolveOutcome has been attached to this request."""
        return self.result is not None


@dataclasses.dataclass
class SolveOutcome:
    """Per-request result (device batch sliced back to the original N).

    ``resnorm`` is the *preconditioned* residual the Krylov iteration
    controlled; ``true_resnorm`` is ||b - A x|| / ||b|| against the
    request's own operator.  ``misconverged`` marks the silent-failure
    mode this engine guards against: the iteration reported
    ``converged`` but the true residual exceeds the guard threshold
    (``opts.check_true_residual``, default ``10 * tol``).  Requests that
    went through the escalation path carry ``escalated=True``; if even
    the escalated re-solve misconverges, ``converged`` is demoted to
    False rather than returning a silently-wrong answer.
    """

    x: np.ndarray
    iterations: float
    resnorm: float
    converged: bool
    cache_hit: bool
    bucket: Tuple[int, int, int]
    variant: str = ""  # SPIKE variant the batch actually solved with
    true_resnorm: float = float("nan")
    misconverged: bool = False
    escalated: bool = False
    # per-sweep Krylov residual track, NaN-padded (opts.record_history)
    history: Optional[np.ndarray] = None


def _opts_sig(opts: SaPOptions) -> tuple:
    """The option fields a cached factorization pytree depends on.

    Part of the LRU key: two factorizations of the same matrix under
    different variants (or precond dtypes, partition counts...) have
    different pytree structures and must never stack into one batch, so
    they live under distinct cache entries.
    """
    return (opts.p, opts.variant, opts.reduced_solver,
            opts.precond_dtype, opts.boost_eps,
            opts.fused_factor, opts.solver)


class SolverEngine:
    """Shape-bucketed, factorization-caching batched solve server.

    opts       : default solver options (p, variant, tol...); per-call
                 overrides ride :meth:`solve_prepared`
    max_batch  : per-step batch-size cap (one bucket per step)
    cache_size : LRU capacity in cached factorizations
    rounding   : bucket rounding policy ("pow2" | "exact")
    cost_accounting : also attribute roofline-predicted flops/bytes/
                 seconds to every step (:mod:`repro.obs.cost`).  Each
                 bucket pays one extra S=1 lowering the first time it is
                 seen; per-batch accounting then scales the S=1 stage
                 costs linearly by batch size (and the Krylov cost by the
                 sweeps the batch actually ran), so the accumulated
                 ``roofline_*`` totals are a model, not a measurement.
    """

    def __init__(
        self,
        opts: Optional[SaPOptions] = None,
        max_batch: int = 32,
        cache_size: int = 128,
        rounding: str = "pow2",
        cost_accounting: bool = False,
    ):
        self.opts = opts or SaPOptions()
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.rounding = rounding
        self.cost_accounting = cost_accounting
        # compile totals are process-wide; remember the engine's epoch so
        # stats_snapshot reports compiles attributable to this engine's
        # lifetime (still process-wide within it: concurrent engines share
        # the XLA compile cache anyway).
        self._compiles0 = obs_cost.COMPILES.totals()
        # accumulated roofline predictions per stage (cost_accounting on)
        self._cost_totals: dict = {}
        self.queue: Deque[SolveRequest] = deque()
        self._next_rid = 0
        # (fingerprint, bucket, opts-sig) -> single-system factorization
        self._cache: OrderedDict = OrderedDict()
        # _lock guards cache + stats + opts (short critical sections);
        # _qlock guards the pending queue.  Device solves hold neither.
        self._lock = threading.RLock()
        self._qlock = threading.Lock()
        self.stats = {
            "submitted": 0,
            "solved": 0,
            "steps": 0,
            "cache_hits": 0,
            "cache_misses": 0,
            "factored_systems": 0,
            "evictions": 0,
            "misconverged": 0,
            "escalations": 0,
            # monotonic wall-clock split of solve_prepared, maintained
            # whether or not a tracer is active: factor_seconds_total is
            # the device-synced batch-factoring of cache misses,
            # solve_seconds_total is everything else (stacking, the
            # batched Krylov solve, unpadding).  solve_seconds is the
            # legacy combined total (= factor + solve), kept for
            # dashboards that already scrape it.
            "factor_seconds_total": 0.0,
            "solve_seconds_total": 0.0,
            "solve_seconds": 0.0,
            # high-water mark of device memory sampled once per step
            # (allocator stats where available, live-array bytes on CPU)
            "peak_device_bytes": 0,
        }

    # -- submission ---------------------------------------------------------

    def submit(self, req: SolveRequest) -> int:
        """Enqueue a prepared request; returns its rid.  Thread-safe."""
        if req.fingerprint is None:  # hash outside any lock (the slow part)
            req.fingerprint = matrix_fingerprint(req.band)
        with self._qlock:
            self.queue.append(req)
        self._bump("submitted")
        return req.rid

    def submit_system(self, band, b) -> int:
        """Convenience wrapper: wrap (band, b) in a request, return its rid."""
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        self.submit(SolveRequest(rid=rid, band=band, b=b))
        return rid

    @property
    def pending(self) -> int:
        """Number of submitted requests not yet drained by a step()."""
        with self._qlock:
            return len(self.queue)

    # -- cache --------------------------------------------------------------

    def _cache_get(self, key):
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
            return hit

    def _cache_put(self, key, value):
        with self._lock:
            self._cache[key] = value
            self._cache.move_to_end(key)
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)
                self.stats["evictions"] += 1

    def _bump(self, key: str, n: float = 1) -> None:
        with self._lock:
            self.stats[key] += n

    @property
    def cached_factorizations(self) -> int:
        """Current number of factorizations held in the LRU cache."""
        with self._lock:
            return len(self._cache)

    # -- the engine tick ----------------------------------------------------

    def step(self) -> List[SolveRequest]:
        """One tick: solve up to ``max_batch`` requests of one bucket.

        Picks the bucket with the most pending requests (largest batch =
        best amortization), factors its cache misses in one vmapped pass,
        then runs one batched solve.  Returns the completed requests.
        """
        with self._qlock:
            if not self.queue:
                return []
            shapes = [
                (np.shape(r.band)[0], (np.shape(r.band)[1] - 1) // 2)
                for r in self.queue
            ]
            with self._lock:
                p, rounding = self.opts.p, self.rounding
            buckets = batched.bucket_by_shape(shapes, p, rounding)
            bucket, idxs = max(buckets.items(), key=lambda kv: len(kv[1]))
            idxs = set(idxs[: self.max_batch])
            batch = [r for i, r in enumerate(self.queue) if i in idxs]
            self.queue = deque(
                r for i, r in enumerate(self.queue) if i not in idxs
            )
        return self.solve_prepared(batch, bucket)

    def solve_prepared(
        self,
        batch: Sequence[SolveRequest],
        bucket: Tuple[int, int, int],
        opts: Optional[SaPOptions] = None,
        _escalated: bool = False,
    ) -> List[SolveRequest]:
        """Solve a pre-formed bucket of requests in one batched pass.

        The re-entrant core of :meth:`step`, also the entry point for the
        async service's drain thread: ``batch`` never touches the engine's
        own queue, so schedulers can form buckets however they like
        (priority, deadlines, dominance class) and hand them over with a
        per-bucket ``opts`` override.  An override must keep ``opts.p``
        consistent with the bucket's partition count.  Safe to call
        concurrently with ``submit``; concurrent calls serialize only on
        the short cache/stats critical sections, not the device solve.

        Every outcome carries the *true* residual ||b - A x|| / ||b||
        alongside the Krylov-controlled preconditioned ``resnorm``.
        Requests whose iteration claims convergence while the true
        residual exceeds the guard (``opts.check_true_residual``, default
        ``10 * tol``) are flagged misconverged and re-solved once through
        :meth:`_escalate` with a structurally exact bucket; ``_escalated``
        marks that inner pass (where a persistent misconvergence demotes
        ``converged`` instead of recursing again).
        """
        batch = list(batch)
        if not batch:
            return []
        nb, kb, _ = bucket
        with span(
            "engine.solve_prepared",
            bucket=f"{nb}x{kb}",
            batch=len(batch),
            escalated=_escalated,
        ) as sp:
            out = self._solve_prepared_impl(batch, bucket, opts, _escalated)
            if sp:
                sp.annotate(
                    variant=out[0].result.variant,
                    cache_hits=sum(1 for r in out if r.result.cache_hit),
                    cache_misses=sum(1 for r in out if not r.result.cache_hit),
                    escalations=sum(1 for r in out if r.result.escalated),
                    fingerprints=[r.fingerprint[:8] for r in out[:8]],
                )
                if self.cost_accounting:
                    try:
                        costs = self.stage_costs(
                            bucket, variant=out[0].result.variant
                        )
                        sp.annotate(
                            cost={n: c.to_dict() for n, c in costs.items()}
                        )
                    except Exception:  # cost model must never fail a solve
                        pass
        return out

    def _solve_prepared_impl(
        self,
        batch: List[SolveRequest],
        bucket: Tuple[int, int, int],
        opts: Optional[SaPOptions],
        _escalated: bool,
    ) -> List[SolveRequest]:
        t0 = time.perf_counter()
        t_factor = 0.0
        nb, kb, _ = bucket
        for r in batch:
            if r.fingerprint is None:
                r.fingerprint = matrix_fingerprint(r.band)

        internal = opts is None
        with self._lock:
            eff = self.opts if internal else opts
        # "auto" resolves per batch from the worst (minimum) host-side
        # dominance estimate, *before* the cache lookup so the resolved
        # variant is part of the cache key.  The internal path stays
        # sticky: the first resolution pins self.opts so every later
        # step stacks structurally identical factorizations.
        if eff.variant == "auto":
            d_min = min(band_dominance(r.band) for r in batch)
            eff = dataclasses.replace(
                eff, variant=resolve_variant("auto", d_min)
            )
            if internal:
                with self._lock:
                    if self.opts.variant == "auto":
                        self.opts = eff
                    eff = self.opts
        sig = _opts_sig(eff)

        # 1) factor the cache misses in ONE vmapped pass.  A batch may
        #    repeat a fingerprint (same Jacobian, many RHS requests): each
        #    distinct matrix is factored once, duplicates count as hits.
        #    ``step_facs`` pins this step's factorizations locally -- the
        #    LRU may evict mid-step (cache_size < distinct matrices in
        #    one batch) without pulling them out from under the solve.
        step_facs: dict = {}
        miss_fps: List[str] = []
        miss_reqs: List[SolveRequest] = []
        is_hit: List[bool] = []
        for r in batch:
            cached = self._cache_get((r.fingerprint, bucket, sig))
            if cached is not None:
                step_facs[r.fingerprint] = cached
                is_hit.append(True)
            elif r.fingerprint in miss_fps:
                is_hit.append(True)
            else:
                is_hit.append(False)
                miss_fps.append(r.fingerprint)
                miss_reqs.append(r)
        if miss_reqs:
            tf0 = time.perf_counter()
            bpl = _plan_for_bucket([r.band for r in miss_reqs], bucket, eff)
            bfac = batched.batch_factor(bpl)
            # block here so the factor-vs-solve wall-clock split is honest
            # (dispatch is async; unsynced, factoring would bill to solve)
            jax.block_until_ready(bfac.fac.pc)
            t_factor = time.perf_counter() - tf0
            for j, fp in enumerate(miss_fps):
                fac = batched.index_factorization(bfac, j)
                step_facs[fp] = fac
                self._cache_put((fp, bucket, sig), fac)
            self._bump("factored_systems", len(miss_reqs))
        self._bump("cache_hits", sum(is_hit))
        self._bump("cache_misses", len(is_hit) - sum(is_hit))

        # 2) one batched solve over cached + fresh factorizations
        facs = [step_facs[r.fingerprint] for r in batch]
        orig_ns = [np.shape(r.band)[0] for r in batch]
        bfac = batched.stack_factorizations(facs, orig_ns)
        bmat = jnp.stack(
            [batched.pad_rhs_to(jnp.asarray(r.b), nb) for r in batch]
        )
        res = bfac.solve_batch(bmat, record_history=eff.record_history)
        xs = batched.unpad_solution(res.x, orig_ns)
        iters = np.asarray(res.iterations)
        rnorm = np.asarray(res.resnorm)
        conv = np.asarray(res.converged)
        hists = np.asarray(res.history) if res.history is not None else None
        if res.true_resnorm is not None:
            tres = np.asarray(res.true_resnorm)
        else:
            tres = np.full(len(batch), np.nan)
        guard = (
            eff.check_true_residual
            if eff.check_true_residual is not None
            else 10.0 * eff.tol
        )
        for i, r in enumerate(batch):
            t = float(tres[i])
            c = bool(conv[i])
            r.result = SolveOutcome(
                x=xs[i],
                iterations=float(iters[i]),
                resnorm=float(rnorm[i]),
                converged=c,
                cache_hit=is_hit[i],
                bucket=bucket,
                variant=eff.variant,
                true_resnorm=t,
                misconverged=bool(c and t > guard),
                history=hists[i] if hists is not None else None,
            )
        dt = time.perf_counter() - t0
        mem = obs_cost.device_memory_bytes()
        with self._lock:
            self.stats["solved"] += len(batch)
            self.stats["steps"] += 1
            self.stats["factor_seconds_total"] += t_factor
            self.stats["solve_seconds_total"] += dt - t_factor
            self.stats["solve_seconds"] += dt
            if mem > self.stats["peak_device_bytes"]:
                self.stats["peak_device_bytes"] = mem

        if self.cost_accounting:
            self._account_cost(bucket, eff, len(batch), len(miss_reqs), iters)

        mis = [r for r in batch if r.result.misconverged]
        if mis:
            self._bump("misconverged", len(mis))
            if _escalated:
                # the exact-bucket pass ALSO misconverged: never report a
                # silently-wrong answer as success
                for r in mis:
                    r.result.converged = False
            else:
                self._escalate(mis, eff)
        return batch

    def _escalate(self, reqs: List[SolveRequest], eff: SaPOptions) -> None:
        """Re-solve misconverged requests under structurally exact buckets.

        Misconvergence is, in practice, a padding artifact: a band stored
        (or bucketed) wider than its true bandwidth makes the K-block
        pivots ill-conditioned and the preconditioned residual lies.  The
        escalation trims each band to its effective bandwidth, re-buckets
        under ``"exact"`` rounding (no pow2 widening), and runs one more
        :meth:`solve_prepared` pass per escalation bucket.  The escalated
        outcome replaces the misconverged one; if it *still* misconverges
        the inner pass demotes ``converged`` to False.
        """
        self._bump("escalations", len(reqs))
        groups: dict = {}
        for r in reqs:
            band = np.asarray(r.band)
            trimmed = batched.trim_band_to_effective(band)
            ke = (trimmed.shape[1] - 1) // 2
            bkt = batched.bucket_shape(
                trimmed.shape[0], max(ke, 1), eff.p, "exact"
            )
            groups.setdefault(bkt, []).append((r, trimmed))
        for bkt, members in groups.items():
            sub = [
                SolveRequest(rid=r.rid, band=trimmed, b=r.b)
                for r, trimmed in members
            ]
            self.solve_prepared(sub, bkt, opts=eff, _escalated=True)
            for (r, _), s in zip(members, sub):
                out = s.result
                out.escalated = True
                r.result = out

    def run_until_drained(
        self, max_steps: int = 10_000, on_leftover: str = "warn"
    ) -> List[SolveRequest]:
        """Step until the queue is empty (or ``max_steps`` ticks elapse).

        Hitting the step budget with work still queued is never silent:
        ``on_leftover="warn"`` (default) emits a RuntimeWarning carrying
        the remaining queue depth, ``"raise"`` turns it into a
        RuntimeError -- unfinished requests would otherwise just look
        like missing results.
        """
        done: List[SolveRequest] = []
        steps = 0
        while self.pending and steps < max_steps:
            done.extend(self.step())
            steps += 1
        leftover = self.pending
        if leftover:
            msg = (
                f"run_until_drained stopped after max_steps={max_steps} "
                f"with {leftover} request(s) still queued"
            )
            if on_leftover == "raise":
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return done

    # -- cost accounting ----------------------------------------------------

    def stage_costs(
        self,
        bucket: Tuple[int, int, int],
        s: int = 1,
        variant: Optional[str] = None,
        opts: Optional[SaPOptions] = None,
    ) -> dict:
        """Per-stage roofline costs for one bucket (cached after first use).

        Thin wrapper over :func:`repro.obs.cost.solver_stage_costs` that
        defaults to the engine's own options and resolved variant; the
        returned dict maps stage name -> :class:`repro.obs.cost.StageCost`.
        """
        with self._lock:
            eff = opts or self.opts
        if variant is None:
            variant = eff.variant if eff.variant != "auto" else "C"
        return obs_cost.solver_stage_costs(
            bucket, s=s, opts=eff, variant=variant
        )

    def _account_cost(self, bucket, eff, batch_len, n_factored, iters) -> None:
        """Fold one step's roofline predictions into the running totals.

        The S=1 stage costs scale linearly by batch size; the Krylov cost
        is per-sweep x the sweeps the (lockstep vmapped) batch actually
        ran -- i.e. the max iteration count in the batch.
        """
        try:
            costs = self.stage_costs(bucket, variant=eff.variant, opts=eff)
        except Exception:  # cost model must never fail a solve
            return
        sweeps = float(np.max(iters)) if np.size(iters) else 0.0
        preds = {
            "factor": costs["factor"].scale(float(n_factored)),
            "krylov": costs["krylov"].per_iteration().scale(
                sweeps * batch_len
            ),
        }
        with self._lock:
            for name, c in preds.items():
                ent = self._cost_totals.setdefault(
                    name, {"flops": 0.0, "hbm_bytes": 0.0, "roofline_s": 0.0}
                )
                ent["flops"] += c.flops
                ent["hbm_bytes"] += c.hbm_bytes
                ent["roofline_s"] += c.roofline_s

    def cost_snapshot(self) -> dict:
        """Accumulated per-stage roofline predictions (cost_accounting)."""
        with self._lock:
            return {k: dict(v) for k, v in self._cost_totals.items()}

    # -- derived stats ------------------------------------------------------

    def stats_snapshot(self) -> dict:
        """Consistent copy of the stats dict (for scraping threads), plus
        the process-wide compile telemetry since this engine's creation
        (``recompiles_total`` / ``compile_seconds_total``)."""
        with self._lock:
            snap = dict(self.stats)
        count, seconds = obs_cost.COMPILES.totals()
        snap["recompiles_total"] = count - self._compiles0[0]
        snap["compile_seconds_total"] = round(seconds - self._compiles0[1], 6)
        return snap

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of drained requests served from the factorization cache."""
        with self._lock:
            tot = self.stats["cache_hits"] + self.stats["cache_misses"]
            return self.stats["cache_hits"] / tot if tot else 0.0

    @property
    def systems_per_second(self) -> float:
        """Throughput from the engine's own monotonic accumulators
        (``factor_seconds_total + solve_seconds_total``) -- no external
        wall clock needed, and the split lets callers separate cold
        (factor-heavy) from warm (cache-hit) throughput."""
        with self._lock:
            sec = (
                self.stats["factor_seconds_total"]
                + self.stats["solve_seconds_total"]
            )
            return self.stats["solved"] / sec if sec > 0 else 0.0


def _plan_for_bucket(
    bands: Sequence, bucket: Tuple[int, int, int], opts: SaPOptions
) -> batched.BatchedSaPPlan:
    """Stack bands padded to an *explicit* bucket (no re-derivation).

    Unlike :func:`repro.core.batched.batch_plan`, which infers one bucket
    from the fleet + a rounding policy, the serving path already committed
    to a bucket at scheduling time -- possibly under a different rounding
    than the engine default (the thrash guard widens it at runtime) -- so
    the bucket itself is authoritative here.
    """
    nb, kb, _ = bucket
    stacked = jnp.stack(
        [batched.pad_band_to(jnp.asarray(bd), nb, kb) for bd in bands]
    )
    orig_ns = tuple(int(np.shape(bd)[0]) for bd in bands)
    # per-band stored bandwidths: pad_band_to embeds a K-widened band via
    # the interleaved identity-row permutation, and batch_factor needs the
    # original k of each member to reconstruct those permutations
    orig_ks = tuple(int((np.shape(bd)[1] - 1) // 2) for bd in bands)
    return batched.BatchedSaPPlan(
        bands=stacked, k=kb, n=nb, orig_ns=orig_ns, orig_ks=orig_ks, opts=opts
    )
