from .checkpoint import CheckpointManager  # noqa: F401
from .loop import TrainConfig, TrainLoop, make_train_step, run_with_restarts  # noqa: F401
