"""Checkpointing: atomic, elastic, restart-capable.

Format: one ``step_XXXXXXXX.npz`` per checkpoint holding every leaf under
a path key, written to a temp file and atomically renamed, plus a
``manifest.json``.  Restore rebuilds the pytree from the treedef of a
template and re-shards to whatever mesh the restarted job has (arrays are
stored unsharded; pjit re-shards on first use) -- i.e. a job can come back
with a different device count (elastic restart).

A small background-thread writer keeps the train loop from blocking on
disk (async checkpointing); ``wait()`` joins before the next save.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz has no bf16: store as f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ---- save ----------------------------------------------------------------
    def save(self, step: int, tree) -> None:
        arrays = _flatten_with_paths(tree)  # device_get on caller thread
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, arrays)

    def _write(self, step: int, arrays: dict) -> None:
        tmp = self.dir / f".tmp_step_{step:08d}.npz"
        final = self.dir / f"step_{step:08d}.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        tmp.rename(final)  # atomic on POSIX
        manifest = {"latest_step": step, "time": time.time()}
        mtmp = self.dir / ".manifest.tmp"
        mtmp.write_text(json.dumps(manifest))
        mtmp.rename(self.dir / "manifest.json")
        self._gc()

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*.npz"))
        for old in ckpts[: -self.keep]:
            old.unlink(missing_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        mf = self.dir / "manifest.json"
        if not mf.exists():
            ckpts = sorted(self.dir.glob("step_*.npz"))
            if not ckpts:
                return None
            return int(ckpts[-1].stem.split("_")[1])
        return int(json.loads(mf.read_text())["latest_step"])

    def restore(self, step: int, template):
        """Rebuild a pytree shaped like ``template`` from disk."""
        path = self.dir / f"step_{step:08d}.npz"
        data = np.load(path)
        flat = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat[0]:
            key = "/".join(str(x) for x in p)
            arr = data[key]
            leaves.append(arr.astype(np.asarray(leaf).dtype) if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    def restore_latest(self, template):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, template)
