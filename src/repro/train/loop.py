"""Training loop: microbatched, sharded, fault-tolerant.

* ``make_train_step``: builds the jitted (loss+grad [+accumulation] +
  AdamW [+int8 error-feedback gradient compression]) step with parameter /
  optimizer-state shardings for an optional mesh (ZeRO-1 supported).
* ``TrainLoop``: drives data -> step -> metrics with periodic async
  checkpointing, automatic restart from the latest checkpoint, a
  straggler monitor (per-step wall-time vs. running median), and a fault
  injection hook used by the integration tests to prove crash recovery.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data.pipeline import DataConfig, make_source
from repro.models import get_family
from repro.models.api import ModelConfig

from .checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1  # gradient accumulation factor
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    zero1: bool = False
    grad_compress: bool = False  # int8 error-feedback (cross-pod trick)
    straggler_factor: float = 2.5  # warn when step_time > factor * median
    seed: int = 0


def make_train_step(cfg: ModelConfig, opt_cfg: optim.AdamWConfig,
                    train_cfg: TrainConfig):
    """Returns step(params, opt_state, err_state, batch) -> (...)"""
    fam = get_family(cfg)
    nmicro = train_cfg.microbatches

    def loss_fn(params, batch):
        l, metrics = fam.loss(cfg, params, batch)
        return l, metrics

    def step(params, opt_state, err_state, batch):
        if nmicro == 1:
            (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def micro(carry, mb):
                acc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(lambda a, b: a + b, acc, g)
                return (acc, lacc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            mbs = jax.tree.map(
                lambda x: x.reshape(nmicro, x.shape[0] // nmicro, *x.shape[1:]),
                batch,
            )
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / nmicro, grads)
            l = lsum / nmicro
            metrics = {"nll": l, "aux": jnp.zeros(())}

        if train_cfg.grad_compress:
            grads, err_state = optim.compress.compress_tree(grads, err_state)

        params, opt_state, om = optim.apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {**metrics, **om, "loss": l}
        return params, opt_state, err_state, metrics

    return step


class TrainLoop:
    """Single-controller training driver with restart + straggler monitor."""

    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: optim.AdamWConfig,
        train_cfg: TrainConfig,
        data_cfg: Optional[DataConfig] = None,
        mesh=None,
        fault_hook: Optional[Callable[[int], None]] = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.train_cfg = train_cfg
        self.mesh = mesh
        self.fam = get_family(cfg)
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=256, global_batch=8, seed=train_cfg.seed
        )
        self.source = make_source(self.data_cfg)
        self.ckpt = CheckpointManager(
            train_cfg.checkpoint_dir, keep=train_cfg.keep_checkpoints
        )
        self.fault_hook = fault_hook
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg, train_cfg))
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def init_state(self):
        params = self.fam.init(self.cfg, jax.random.PRNGKey(self.train_cfg.seed))
        opt_state = optim.init(params)
        err_state = (
            optim.compress.init_error_state(params)
            if self.train_cfg.grad_compress
            else jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), {})
        )
        return params, opt_state, err_state

    def run(self, resume: bool = True) -> dict:
        params, opt_state, err_state = self.init_state()
        start_step = 0
        if resume:
            latest = self.ckpt.latest_step()
            if latest is not None:
                tmpl = {"params": params, "opt": opt_state, "err": err_state}
                restored = self.ckpt.restore(latest, tmpl)
                params = restored["params"]
                opt_state = restored["opt"]
                err_state = restored["err"]
                start_step = latest
        times: list[float] = []
        step = start_step
        metrics = {"loss": jnp.nan, "grad_norm": jnp.nan, "lr": jnp.nan}
        while step < self.train_cfg.steps:
            batch_np = self.source.batch(step)
            batch = jax.tree.map(jnp.asarray, batch_np)
            t0 = time.perf_counter()
            if self.fault_hook is not None:
                self.fault_hook(step)  # may raise to simulate a crash
            params, opt_state, err_state, metrics = self.step_fn(
                params, opt_state, err_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            med = float(np.median(times[-50:]))
            straggler = len(times) > 5 and dt > self.train_cfg.straggler_factor * med
            step += 1
            if step % self.train_cfg.log_every == 0 or step == self.train_cfg.steps:
                row = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]),
                    "step_time_s": dt,
                    "straggler": bool(straggler),
                }
                self.metrics_log.append(row)
            if step % self.train_cfg.checkpoint_every == 0:
                self.ckpt.save(
                    step, {"params": params, "opt": opt_state, "err": err_state}
                )
        self.ckpt.wait()
        return {
            "params": params,
            "opt": opt_state,
            "final_loss": float(metrics["loss"]),
            "log": self.metrics_log,
            "last_step": step,
        }


def run_with_restarts(loop_factory: Callable[[], TrainLoop], max_restarts: int = 3):
    """Supervisor: restart the loop from the latest checkpoint on crash.

    This is the single-host stand-in for a cluster-level job controller:
    the same checkpoint/resume path handles a real preemption."""
    attempts = 0
    while True:
        loop = loop_factory()
        try:
            return loop.run(resume=True), attempts
        except RuntimeError:
            attempts += 1
            if attempts > max_restarts:
                raise
