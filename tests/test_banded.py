"""Unit tests: band storage, conversions, matvec, partitioning."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.banded import (
    band_matvec,
    band_to_block_tridiag,
    band_to_dense,
    block_tridiag_to_dense,
    dense_to_band,
    pad_banded,
    padded_partition_size,
    partition_sizes,
    random_banded,
    random_rhs,
)


@pytest.mark.parametrize("n,k", [(17, 2), (32, 5), (64, 1), (10, 9)])
def test_band_dense_roundtrip(n, k):
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=0))
    dense = band_to_dense(band)
    band2 = dense_to_band(dense, k)
    np.testing.assert_allclose(np.asarray(band), np.asarray(band2))


@pytest.mark.parametrize("n,k,r", [(33, 3, 1), (40, 6, 4)])
def test_band_matvec_matches_dense(n, k, r):
    band = jnp.asarray(random_banded(n, k, d=0.8, seed=1))
    dense = np.asarray(band_to_dense(band))
    x = np.random.default_rng(2).normal(size=(n, r))
    got = np.asarray(band_matvec(band, jnp.asarray(x)))
    np.testing.assert_allclose(got, dense @ x, rtol=2e-4, atol=1e-5)
    got1 = np.asarray(band_matvec(band, jnp.asarray(x[:, 0])))
    np.testing.assert_allclose(got1, dense @ x[:, 0], rtol=2e-4, atol=1e-5)


def test_partition_sizes_paper_rule():
    # paper Sec 3.1: first P_r partitions get floor(N/P)+1 rows
    sizes = partition_sizes(10, 3)
    assert sizes.tolist() == [4, 3, 3]
    assert padded_partition_size(100, 4, 8) % 8 == 0


@pytest.mark.parametrize("n,k,p", [(60, 4, 3), (100, 7, 5), (64, 8, 2)])
def test_block_tridiag_reassembly(n, k, p):
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=3))
    bt = band_to_block_tridiag(band, k, p)
    band_p, _ = pad_banded(band, jnp.zeros((n,)), bt.n_pad)
    dense_pad = np.asarray(band_to_dense(band_p))
    dense_bt = np.asarray(block_tridiag_to_dense(bt))
    np.testing.assert_allclose(dense_pad, dense_bt, atol=1e-6)


def test_pad_banded_identity_rows():
    band = jnp.asarray(random_banded(10, 2, d=1.0, seed=0))
    band_p, b_p = pad_banded(band, jnp.ones((10,)), 16)
    dense = np.asarray(band_to_dense(band_p))
    # padded rows are identity
    np.testing.assert_allclose(dense[10:, 10:], np.eye(6))
    assert np.all(np.asarray(b_p)[10:] == 0.0)


def test_random_banded_dominance():
    for d in (0.5, 1.0, 2.0):
        band = random_banded(50, 4, d=d, seed=0)
        off = np.abs(band).sum(axis=1) - np.abs(band[:, 4])
        ratio = np.abs(band[:, 4]) / np.maximum(off, 1e-12)
        np.testing.assert_allclose(ratio, d, rtol=1e-6)


def test_random_rhs_parabola():
    b = random_rhs(101)
    assert b[0] == pytest.approx(1.0)
    assert b.max() > 300.0
