"""Batched many-systems lifecycle: bucketing, padding exactness, parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SaPOptions,
    batch_factor,
    batch_plan,
    bucket_by_shape,
    bucket_shape,
    factor,
    index_factorization,
    pad_band_to,
    pad_rhs_to,
    plan_banded,
    stack_factorizations,
    unpad_solution,
)
from repro.core.banded import (
    band_matvec,
    band_to_dense,
    oscillatory_banded,
    random_banded,
)


def _system(n, k, d=1.0, seed=0):
    band = jnp.asarray(random_banded(n, k, d=d, seed=seed), jnp.float32)
    rng = np.random.default_rng(seed + 100)
    x = rng.normal(size=n)
    b = band_matvec(band, jnp.asarray(x, jnp.float32))
    return band, x, b


# ---------------------------------------------------------------------------
# bucketing helpers
# ---------------------------------------------------------------------------


def test_bucket_shape_invariants():
    for n, k, p in [(100, 3, 4), (4096, 16, 8), (10_001, 7, 16), (8, 1, 2)]:
        nb, kb, pb = bucket_shape(n, k, p)
        assert nb >= n and kb >= max(k, 2) and pb == p
        assert nb % (p * kb) == 0  # bucket key IS the compiled shape
        # idempotent: a bucket maps to itself
        assert bucket_shape(nb, kb, p) == (nb, kb, p)


def test_bucket_shape_exact_vs_pow2():
    # pow2 widens K 5 -> 8; the bucket must also hold the interleaved
    # identity-row embedding: ceil(1000/5)*8 = 1600 rows -> next pow2
    assert bucket_shape(1000, 5, 4, "pow2") == (2048, 8, 4)
    nb, kb, _ = bucket_shape(1000, 5, 4, "exact")
    assert kb == 5 and nb >= 1000 and nb % (4 * 5) == 0
    with pytest.raises(ValueError):
        bucket_shape(100, 3, 4, "nope")


def test_bucket_by_shape_groups_and_order():
    shapes = [(1000, 5), (900, 6), (1024, 8), (100, 2), (1000, 5)]
    buckets = bucket_by_shape(shapes, p=4)
    # pow2 + interleave room: (1000,5)->(2048,8), (900,6)->(2048,8),
    # (1024,8)->(1024,8) (K not widened -> no interleave growth)
    assert buckets[(2048, 8, 4)] == [0, 1, 4]
    assert buckets[(1024, 8, 4)] == [2]
    assert buckets[(128, 2, 4)] == [3]
    # exact mode separates distinct shapes
    assert len(bucket_by_shape(shapes, p=4, rounding="exact")) == 4


def test_pad_band_to_rejects_shrink():
    band, _, _ = _system(64, 3)
    with pytest.raises(ValueError):
        pad_band_to(band, 32, 3)
    with pytest.raises(ValueError):
        pad_band_to(band, 64, 2)


def test_padded_system_is_exactly_embedded():
    """Identity-row/zero-column padding decouples exactly: the dense
    padded matrix is blkdiag(A, I), so its solution is [x; 0]."""
    band, xstar, b = _system(60, 4, seed=3)
    padded = pad_band_to(band, 96, 7)
    dense_p = np.asarray(band_to_dense(padded), np.float64)
    dense = np.asarray(band_to_dense(band), np.float64)
    np.testing.assert_array_equal(dense_p[:60, :60], dense)
    np.testing.assert_array_equal(dense_p[60:, :60], 0.0)
    np.testing.assert_array_equal(dense_p[:60, 60:], 0.0)
    np.testing.assert_array_equal(dense_p[60:, 60:], np.eye(36))
    xp = np.linalg.solve(dense_p, np.asarray(pad_rhs_to(b, 96), np.float64))
    np.testing.assert_allclose(xp[:60], np.linalg.solve(dense, np.asarray(b)),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_array_equal(xp[60:], 0.0)


def test_k_padded_band_is_permuted_blkdiag():
    """When the bucket widens K, pad_band_to interleaves identity rows so
    the padded dense matrix is a symmetric permutation of blkdiag(A, I) --
    no structurally-singular outer diagonal, no boosted pivots."""
    from repro.core import pad_permutation

    n, k, nb, kb = 60, 3, 128, 4
    band, _, _ = _system(n, k, seed=5)
    perm = pad_permutation(n, k, nb, kb)
    assert perm is not None  # K widened and the bucket has room
    padded = pad_band_to(band, nb, kb)
    dense_p = np.asarray(band_to_dense(padded), np.float64)
    dense = np.asarray(band_to_dense(band), np.float64)
    blk = np.eye(nb)
    blk[:n, :n] = dense
    # dense_p == P @ blk @ P^T with P the interleave row permutation
    p_mat = np.zeros((nb, nb))
    p_mat[perm, np.arange(nb)] = 1.0
    np.testing.assert_array_equal(dense_p, p_mat @ blk @ p_mat.T)
    # the padded band still only occupies |offset| <= kb diagonals
    assert padded.shape == (nb, 2 * kb + 1)


# ---------------------------------------------------------------------------
# batched lifecycle parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["C", "D", "E"])
def test_solve_batch_matches_per_system(variant):
    opts = SaPOptions(p=4, variant=variant, tol=1e-6, maxiter=300)
    systems = [_system(320, 5, seed=i) for i in range(4)]
    bpl = batch_plan([s[0] for s in systems], opts)
    bfac = batch_factor(bpl)
    bmat = jnp.stack([pad_rhs_to(s[2], bpl.n) for s in systems])
    res = bfac.solve_batch(bmat)
    assert bool(np.asarray(res.converged).all())
    assert res.x.shape == (4, bpl.n)
    for i, (band, xstar, b) in enumerate(systems):
        one = index_factorization(bfac, i).solve(bmat[i])
        np.testing.assert_allclose(
            np.asarray(res.x[i]), np.asarray(one.x), rtol=1e-5, atol=1e-6
        )
        err = np.linalg.norm(np.asarray(res.x[i, :320]) - xstar)
        assert err / np.linalg.norm(xstar) < 1e-3


def test_heterogeneous_nk_batch_matches_unpadded_solves():
    """Systems of different (N, K) share one bucket; each padded solve
    agrees with its standalone unpadded solve to iteration tolerance."""
    opts = SaPOptions(p=4, variant="C", tol=1e-8, maxiter=400)
    systems = [_system(200, 3, seed=0), _system(301, 5, seed=1),
               _system(256, 4, seed=2)]
    bpl = batch_plan([s[0] for s in systems], opts)
    assert bpl.orig_ns == (200, 301, 256)
    assert bpl.n >= 301 and bpl.k == 8
    bfac = batch_factor(bpl)
    res = bfac.solve_batch(
        jnp.stack([pad_rhs_to(s[2], bpl.n) for s in systems])
    )
    assert bool(np.asarray(res.converged).all())
    xs = unpad_solution(res.x, bpl.orig_ns)
    for (band, xstar, b), x in zip(systems, xs):
        solo = factor(plan_banded(band, opts)).solve(b)
        np.testing.assert_allclose(x, np.asarray(solo.x), rtol=2e-4, atol=2e-5)
        # padded rows came back exactly zero-trimmed
        assert x.shape == xstar.shape


def test_bucket_of_size_one():
    band, xstar, b = _system(320, 5)
    opts = SaPOptions(p=4, tol=1e-6, maxiter=300)
    bfac = batch_factor(batch_plan([band], opts))
    assert bfac.s == 1
    res = bfac.solve_batch(pad_rhs_to(b, bfac.n)[None])
    assert bool(np.asarray(res.converged).all())
    err = np.linalg.norm(np.asarray(res.x[0, :320]) - xstar)
    assert err / np.linalg.norm(xstar) < 1e-3


def test_solve_batch_many_matches_columns():
    opts = SaPOptions(p=4, tol=1e-6, maxiter=300)
    systems = [_system(256, 4, seed=i) for i in range(3)]
    bpl = batch_plan([s[0] for s in systems], opts)
    bfac = batch_factor(bpl)
    rng = np.random.default_rng(9)
    bmany = jnp.asarray(rng.normal(size=(3, bpl.n, 2)), jnp.float32)
    res = bfac.solve_batch_many(bmany)
    assert res.x.shape == (3, bpl.n, 2)
    assert res.iterations.shape == (3, 2)
    for j in range(2):
        col = bfac.solve_batch(bmany[:, :, j])
        np.testing.assert_allclose(
            np.asarray(res.x[:, :, j]), np.asarray(col.x), rtol=1e-5,
            atol=1e-6
        )


def test_solve_batch_shape_errors():
    band, _, b = _system(320, 5)
    bfac = batch_factor(batch_plan([band], SaPOptions(p=4)))
    with pytest.raises(ValueError, match="one RHS per system"):
        bfac.solve_batch(pad_rhs_to(b, bfac.n))  # missing system axis
    with pytest.raises(ValueError, match="solve_batch_many"):
        bfac.solve_batch_many(pad_rhs_to(b, bfac.n)[None])


def test_auto_variant_resolves_from_worst_system():
    opts = SaPOptions(p=4, variant="auto", tol=1e-5, maxiter=100)
    dominant = jnp.asarray(random_banded(256, 4, d=1.5, seed=0), jnp.float32)
    hard = jnp.asarray(oscillatory_banded(256, 4, d=0.5, seed=1), jnp.float32)
    assert batch_factor(batch_plan([dominant], opts)).variant == "C"
    # one non-dominant member drags the whole batch to the exact variant
    assert batch_factor(batch_plan([dominant, hard], opts)).variant == "E"


def test_batched_factorization_is_a_pytree():
    systems = [_system(256, 4, seed=i) for i in range(2)]
    bfac = batch_factor(
        batch_plan([s[0] for s in systems], SaPOptions(p=4, tol=1e-6))
    )
    leaves, treedef = jax.tree_util.tree_flatten(bfac)
    bfac2 = jax.tree_util.tree_unflatten(treedef, leaves)
    bmat = jnp.stack([pad_rhs_to(s[2], bfac.n) for s in systems])

    @jax.jit
    def through_jit(bf, bb):
        return bf.solve_batch(bb)

    r1 = bfac.solve_batch(bmat)
    r2 = through_jit(bfac2, bmat)
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))


def test_stack_factorizations_rejects_mixed_buckets():
    f1 = factor(plan_banded(_system(256, 4)[0], SaPOptions(p=4)))
    f2 = factor(plan_banded(_system(128, 4)[0], SaPOptions(p=4)))
    with pytest.raises(ValueError, match="different buckets"):
        stack_factorizations([f1, f2])
    with pytest.raises(ValueError, match="at least one"):
        stack_factorizations([])


def test_batch_plan_accepts_stacked_array():
    bands = jnp.stack([_system(256, 4, seed=i)[0] for i in range(3)])
    bpl = batch_plan(bands, SaPOptions(p=4))
    assert bpl.s == 3 and bpl.orig_ns == (256, 256, 256)
    assert batch_factor(bpl).s == 3
