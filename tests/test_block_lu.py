"""Unit tests: Gauss-Jordan with boosting, block-tridiag LU/UL factor+solve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.banded import (
    band_to_block_tridiag,
    block_tridiag_to_dense,
    random_banded,
)
from repro.core.block_lu import (
    btf_ref,
    btf_ul_ref,
    bts_ref,
    flip_block_tridiag,
    gj_inverse,
)


def test_gj_inverse_matches_numpy():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(12, 12)) + 6 * np.eye(12)
    inv = np.asarray(gj_inverse(jnp.asarray(a)))
    np.testing.assert_allclose(inv, np.linalg.inv(a), rtol=1e-5, atol=1e-6)


def test_gj_inverse_pivot_boosting_no_nan():
    # singular block: plain GJ would divide by zero; boosting must not NaN
    a = jnp.zeros((6, 6)).at[0, 0].set(1.0)
    inv = gj_inverse(a, boost_eps=1e-8)
    assert bool(jnp.all(jnp.isfinite(inv)))


@pytest.mark.parametrize("n,k,p,r", [(60, 4, 3, 1), (96, 8, 2, 5), (70, 5, 7, 2)])
def test_block_lu_solves_partition_systems(n, k, p, r):
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=7))
    bt = band_to_block_tridiag(band, k, p)
    fac = btf_ref(bt.d, bt.e, bt.f)
    rng = np.random.default_rng(1)
    rhs = jnp.asarray(rng.normal(size=(bt.p, bt.m, bt.k, r)))
    x = bts_ref(fac, rhs)
    dense = np.asarray(block_tridiag_to_dense(bt))
    ni = bt.m * bt.k
    for i in range(p):
        ai = dense[i * ni : (i + 1) * ni, i * ni : (i + 1) * ni]
        xi = np.asarray(x[i]).reshape(ni, r)
        bi = np.asarray(rhs[i]).reshape(ni, r)
        np.testing.assert_allclose(ai @ xi, bi, rtol=1e-3, atol=1e-3)


def test_flip_is_reversal_conjugation():
    band = jnp.asarray(random_banded(48, 4, d=1.0, seed=2))
    bt = band_to_block_tridiag(band, 4, 2)
    d_r, e_r, f_r = flip_block_tridiag(bt.d, bt.e, bt.f)
    # reassemble flipped partition 0 and compare against J A J^T
    import dataclasses

    bt_r = dataclasses.replace(bt, d=d_r, e=e_r, f=f_r)
    a = np.asarray(block_tridiag_to_dense(bt))
    a_r = np.asarray(block_tridiag_to_dense(bt_r))
    ni = bt.m * bt.k
    a0 = a[:ni, :ni]
    np.testing.assert_allclose(a_r[:ni, :ni], a0[::-1, ::-1], atol=1e-6)


def test_ul_factor_solves_like_lu():
    band = jnp.asarray(random_banded(64, 4, d=1.2, seed=3))
    bt = band_to_block_tridiag(band, 4, 2)
    ul = btf_ul_ref(bt.d, bt.e, bt.f)
    rng = np.random.default_rng(4)
    rhs = jnp.asarray(rng.normal(size=(bt.p, bt.m, bt.k, 1)))
    # solving the reversed system with reversed rhs gives reversed solution
    rhs_rev = rhs[:, ::-1, ::-1, :]
    x_rev = bts_ref(ul, rhs_rev)
    x = x_rev[:, ::-1, ::-1, :]
    fac = btf_ref(bt.d, bt.e, bt.f)
    x_lu = bts_ref(fac, rhs)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_lu), rtol=1e-2, atol=1e-3)
