"""Krylov convergence history: shape, NaN padding, parity, cache safety."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_matvec, random_banded
from repro.core.krylov import bicgstab2, cg
from repro.serve import SolverEngine


def _system(n=320, k=5, d=1.0, seed=11):
    band = jnp.asarray(random_banded(n, k, d=d, seed=seed), jnp.float32)
    rng = np.random.default_rng(seed + 1)
    xstar = rng.normal(size=n)
    b = band_matvec(band, jnp.asarray(xstar, jnp.float32))
    return band, xstar, b


def _recorded(history):
    hist = np.asarray(history)
    return hist[~np.isnan(hist)]


# ---------------------------------------------------------------------------
# lifecycle path (BiCGStab(2))
# ---------------------------------------------------------------------------


def test_history_length_and_nan_tail():
    band, _, b = _system()
    opts = SaPOptions(p=4, variant="C", tol=1e-8, maxiter=100)
    fac = factor(plan_banded(band, opts))
    res = fac.solve(b, record_history=True)
    assert bool(res.converged)
    hist = np.asarray(res.history)
    assert hist.shape == (opts.maxiter,)
    track = _recorded(res.history)
    # one entry per completed sweep: ceil of the fractional iteration count
    assert track.size == int(np.ceil(float(res.iterations)))
    # the tail past the last sweep is entirely NaN padding
    assert np.isnan(hist[track.size:]).all()
    # the final recorded (preconditioned) residual is the converged one
    assert track[-1] <= opts.tol
    assert track[-1] == pytest.approx(float(res.resnorm), rel=1e-5, abs=1e-12)


def test_history_default_is_none_and_pytree_unchanged():
    band, _, b = _system()
    opts = SaPOptions(p=4, variant="C", tol=1e-8, maxiter=100)
    fac = factor(plan_banded(band, opts))
    plain = fac.solve(b)
    assert plain.history is None
    # the default result pytree must not grow a new leaf (cache identity:
    # record_history is a separate jit entry, the default one is untouched)
    recorded = fac.solve(b, record_history=True)
    plain_leaves = len(jax.tree_util.tree_leaves(plain))
    assert len(jax.tree_util.tree_leaves(recorded)) == plain_leaves + 1
    np.testing.assert_allclose(
        np.asarray(plain.x), np.asarray(recorded.x), rtol=1e-6
    )
    assert float(plain.iterations) == float(recorded.iterations)


def test_history_solve_many_parity():
    band, _, b = _system()
    opts = SaPOptions(p=4, variant="C", tol=1e-8, maxiter=100)
    fac = factor(plan_banded(band, opts))
    one = fac.solve(b, record_history=True)
    many = fac.solve_many(jnp.stack([b, 2.0 * b], axis=1), record_history=True)
    hist = np.asarray(many.history)
    assert hist.shape == (2, opts.maxiter)
    # column 0 is the same system: identical residual track
    np.testing.assert_allclose(
        hist[0], np.asarray(one.history), rtol=1e-5, equal_nan=True
    )
    # a scaled RHS converges along its own (relative) track too
    assert _recorded(hist[1])[-1] <= opts.tol


def test_history_decreases_on_dominant_system():
    band, _, b = _system(d=1.5)
    opts = SaPOptions(p=4, variant="C", tol=1e-8, maxiter=100)
    fac = factor(plan_banded(band, opts))
    track = _recorded(fac.solve(b, record_history=True).history)
    assert track[-1] < track[0]


# ---------------------------------------------------------------------------
# raw Krylov drivers
# ---------------------------------------------------------------------------


def test_cg_history():
    n = 64
    rng = np.random.default_rng(0)
    q = rng.normal(size=(n, n))
    a = jnp.asarray(q @ q.T + n * np.eye(n), jnp.float32)
    b = jnp.asarray(rng.normal(size=n), jnp.float32)
    res = cg(lambda v: a @ v, b, tol=1e-6, maxiter=80, record_history=True)
    assert bool(res.converged)
    hist = np.asarray(res.history)
    assert hist.shape == (80,)
    track = _recorded(res.history)
    assert track.size == int(float(res.iterations))
    assert track[-1] <= 1e-6


def test_bicgstab2_history_off_is_none():
    n = 64
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(n, n)) + n * np.eye(n), jnp.float32)
    b = jnp.asarray(rng.normal(size=n), jnp.float32)
    res = bicgstab2(lambda v: a @ v, b, tol=1e-6, maxiter=50)
    assert res.history is None
    res_h = bicgstab2(
        lambda v: a @ v, b, tol=1e-6, maxiter=50, record_history=True
    )
    assert res_h.history is not None
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(res_h.x))


# ---------------------------------------------------------------------------
# engine path (SaPOptions.record_history -> SolveOutcome.history)
# ---------------------------------------------------------------------------


def test_engine_outcome_history():
    opts = SaPOptions(
        p=4, variant="C", tol=1e-6, maxiter=200, record_history=True
    )
    eng = SolverEngine(opts, max_batch=8)
    for seed in range(3):
        band = np.float32(random_banded(256, 4, d=1.1, seed=seed))
        b = np.random.default_rng(seed).normal(size=256).astype(np.float32)
        eng.submit_system(band, b)
    done = eng.run_until_drained()
    assert len(done) == 3
    for r in done:
        out = r.result
        assert out.converged
        assert out.history is not None and out.history.shape == (opts.maxiter,)
        assert _recorded(out.history).size == int(np.ceil(out.iterations))


def test_engine_history_default_off():
    eng = SolverEngine(SaPOptions(p=4, variant="C", tol=1e-6), max_batch=8)
    band = np.float32(random_banded(256, 4, d=1.1, seed=7))
    b = np.random.default_rng(7).normal(size=256).astype(np.float32)
    eng.submit_system(band, b)
    (done,) = eng.run_until_drained()
    assert done.result.history is None
