"""Cost observatory tests: roofline accounting, compile/memory telemetry,
and the bench-trajectory regression gate.

Covers repro.obs.cost (HLO-derived per-stage FLOPs/bytes vs the analytic
models in repro.kernels.ops, the AOT compile cache / compile counters),
the engine/service telemetry surfacing, and benchmarks/trajectory.py +
benchmarks/check_regression.py (synthetic histories: injected slowdown
fails, noise passes, bless resets the baseline).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions, batch_factor, batch_plan
from repro.core.banded import band_matvec, random_banded
from repro.kernels import ops
from repro.obs import cost
from repro.obs.trace import Tracer, use_tracer
from repro.serve.service import AsyncSolverService
from repro.serve.solver_engine import SolverEngine

from benchmarks import check_regression, trajectory


# ---------------------------------------------------------------------------
# hardware model
# ---------------------------------------------------------------------------


def test_hardware_spec_defaults():
    hw = cost.hardware_spec()
    assert hw.peak_flops > 0 and hw.hbm_bw > 0
    assert cost.hardware_spec("gpu").name == "gpu-a100"
    assert cost.hardware_spec("tpu").peak_flops > cost.hardware_spec(
        "cpu").peak_flops


def test_hardware_spec_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "1e15")
    monkeypatch.setenv("REPRO_HBM_BW", "2e12")
    hw = cost.hardware_spec("cpu")
    assert hw.peak_flops == 1e15
    assert hw.hbm_bw == 2e12
    assert hw.name.endswith("+env")


# ---------------------------------------------------------------------------
# StageCost arithmetic + cost_of on a known kernel
# ---------------------------------------------------------------------------


def test_cost_of_matmul_exact_flops():
    n = 64
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    c = cost.cost_of(lambda x, y: x @ y, a, a, stage="matmul")
    # one dot: exactly 2 n^3 flops (the HLO walk counts dots analytically)
    assert c.flops == pytest.approx(2.0 * n**3, rel=0.05)
    # two inputs + one output, f32
    assert c.hbm_bytes == pytest.approx(3 * n * n * 4, rel=0.25)
    assert c.intensity == pytest.approx(c.flops / c.hbm_bytes)


def test_stage_cost_roofline_identity():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = cost.cost_of(lambda x: x + 1.0, a, stage="add")
    assert c.roofline_s == max(c.compute_s, c.memory_s)
    assert c.bottleneck in ("compute", "memory")
    # elementwise add is memory bound on any sane hardware model
    assert c.bottleneck == "memory"


def test_stage_cost_scale_and_per_iteration():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = cost.cost_of(
        lambda x: x * 2.0, a, stage="mul", loop_iters=10
    )
    one = c.per_iteration()
    assert one.flops == pytest.approx(c.flops / 10)
    assert one.loop_iters is None
    tripled = one.scale(3)
    assert tripled.flops == pytest.approx(3 * one.flops)
    assert tripled.roofline_s == pytest.approx(3 * one.roofline_s)
    d = tripled.to_dict(measured_s=2 * tripled.roofline_s)
    assert d["roofline_frac"] == pytest.approx(0.5, rel=1e-3)


# ---------------------------------------------------------------------------
# solver stage costs vs the analytic models
# ---------------------------------------------------------------------------

OPTS = SaPOptions(p=4, variant="C", tol=1e-6, maxiter=50)
BUCKET = (256, 4, 4)


def test_solver_stage_costs_stages_present():
    costs = cost.solver_stage_costs(BUCKET, s=1, opts=OPTS)
    for stage in ("factor", "krylov", "btf", "bts"):
        assert stage in costs, stage
        assert costs[stage].flops > 0
        assert costs[stage].hbm_bytes > 0
    assert costs["krylov"].loop_iters == OPTS.maxiter


def test_solver_stage_costs_cached():
    first = cost.solver_stage_costs(BUCKET, s=1, opts=OPTS)
    again = cost.solver_stage_costs(BUCKET, s=1, opts=OPTS)
    assert first is again  # same dict object: served from the cache


def test_btf_bts_flops_within_analytic_band():
    """The HLO walk counts every lowered op, so it sits above the
    leading-order algebraic count -- but only by a bounded factor."""
    costs = cost.solver_stage_costs(BUCKET, s=1, opts=OPTS)
    nb, kb, p = BUCKET
    m = nb // (p * kb)
    for stage, analytic in (
        ("btf", ops.btf_flops(p, m, kb)),
        ("bts", ops.bts_flops(p, m, kb)),
    ):
        ratio = costs[stage].flops / analytic
        assert 1.0 <= ratio <= 20.0, (stage, ratio)


def test_bcr_flops_within_analytic_band():
    opts_e = SaPOptions(p=4, variant="E", reduced_solver="bcr",
                        tol=1e-6, maxiter=50)
    costs = cost.solver_stage_costs(BUCKET, s=1, opts=opts_e, variant="E")
    assert "bcr" in costs
    ratio = costs["bcr"].flops / ops.bcr_flops(opts_e.p - 1, 2 * BUCKET[1])
    assert 1.0 <= ratio <= 20.0, ratio


# ---------------------------------------------------------------------------
# compile telemetry
# ---------------------------------------------------------------------------


def test_compile_counter_first_vs_cached_bucket():
    opts = SaPOptions(p=2, variant="C", tol=1e-6, maxiter=20)
    bands = [jnp.asarray(random_banded(96, 2, d=1.2, seed=s), jnp.float32)
             for s in range(2)]

    def labeled_factor_compiles():
        ent = cost.COMPILES.snapshot()["labels"].get("factor.batch")
        return ent["count"] if ent else 0

    before = labeled_factor_compiles()
    batch_factor(batch_plan(bands, opts))
    first = labeled_factor_compiles() - before
    batch_factor(batch_plan(bands, opts))
    second = labeled_factor_compiles() - before - first
    # a fresh bucket shape pays exactly one factor-stages compile; the
    # second batch_factor of the same bucket reuses the AOT executable
    assert first == 1
    assert second == 0


def test_device_memory_bytes_positive():
    x = jnp.ones((128, 128))  # keep at least one live array around
    assert cost.device_memory_bytes() > 0
    del x


# ---------------------------------------------------------------------------
# engine + service surfacing
# ---------------------------------------------------------------------------


def _one_system(n=96, k=2, seed=0):
    band = np.float32(random_banded(n, k, d=1.2, seed=seed))
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    b = np.asarray(band_matvec(jnp.asarray(band), jnp.asarray(x)))
    return band, b


def test_engine_cost_accounting_and_telemetry():
    opts = SaPOptions(p=2, variant="C", tol=1e-6, maxiter=30)
    eng = SolverEngine(opts, max_batch=8, cache_size=8,
                       cost_accounting=True)
    tracer = Tracer()
    with use_tracer(tracer):
        band, b = _one_system(seed=1)
        eng.submit_system(band, b)
        done = eng.run_until_drained()
    assert done and all(r.result.converged for r in done)

    snap = eng.stats_snapshot()
    assert snap["recompiles_total"] >= 1
    assert snap["compile_seconds_total"] > 0
    assert snap["peak_device_bytes"] > 0

    totals = eng.cost_snapshot()
    assert totals["factor"]["flops"] > 0
    assert totals["krylov"]["roofline_s"] > 0

    # the solve span carries the per-stage cost records
    spans = tracer.find("engine.solve_prepared")
    assert spans
    c = spans[0].attrs.get("cost")
    assert c and c["factor"]["flops"] > 0 and "roofline_s" in c["krylov"]


def test_service_prometheus_has_cost_series():
    opts = SaPOptions(p=2, variant="C", tol=1e-6, maxiter=30)
    svc = AsyncSolverService(opts, start=False, cost_accounting=True)
    band, b = _one_system(seed=2)
    fut = svc.submit(band, b)
    while svc.pending:
        svc.drain_once()
    assert fut.result(5).converged

    prom = svc.render()
    assert "recompiles_total" in prom
    assert "compile_seconds_total" in prom
    assert "peak_device_bytes" in prom
    snap = svc.snapshot()
    assert snap["gauges"]["peak_device_bytes"] > 0


# ---------------------------------------------------------------------------
# trajectory + regression gate
# ---------------------------------------------------------------------------


def _doc(us, bench="batched", row="fleet/batched_S=8", t=1000,
         backend="cpu", smoke=True):
    return {
        "bench": bench,
        "unix_time": t,
        "platform": {"backend": backend, "machine": "x86_64",
                     "device_count": 1},
        "meta": {"smoke": smoke},
        "rows": [{"name": row, "us_per_call": us, "derived": {}}],
    }


def test_trajectory_roundtrip(tmp_path):
    hist = tmp_path / "h.jsonl"
    assert trajectory.load_history(hist) == []
    trajectory.append_history(_doc(100.0, t=1), hist)
    trajectory.append_history(_doc(110.0, t=2), hist)
    recs = trajectory.load_history(hist)
    assert len(recs) == 2
    base = trajectory.baseline_records(
        recs, "batched", "fleet/batched_S=8", "cpu/x86_64/d1", True)
    assert [r["us_per_call"] for r in base] == [100.0, 110.0]
    # platform / smoke filters
    assert not trajectory.baseline_records(
        recs, "batched", "fleet/batched_S=8", "gpu/x86_64/d1", True)
    assert not trajectory.baseline_records(
        recs, "batched", "fleet/batched_S=8", "cpu/x86_64/d1", False)


def test_trajectory_doc_path_input(tmp_path):
    doc_path = tmp_path / "BENCH_x.json"
    doc_path.write_text(json.dumps(_doc(50.0)))
    hist = tmp_path / "h.jsonl"
    assert trajectory.append_history(doc_path, hist) == 1
    assert trajectory.load_history(hist)[0]["us_per_call"] == 50.0


def test_regression_gate_fails_on_2x_slowdown(tmp_path):
    hist = tmp_path / "h.jsonl"
    for t, us in enumerate((100.0, 102.0, 98.0)):
        trajectory.append_history(_doc(us, t=t), hist)
    with pytest.raises(check_regression.RegressionError) as err:
        check_regression.check([_doc(200.0, t=9)], hist, tolerance=1.5)
    assert "fleet/batched_S=8" in str(err.value)
    # the CLI path exits 1 on the same regression (what fails CI)
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(_doc(200.0, t=9)))
    assert check_regression.main(
        [str(cur), "--history", str(hist), "--tolerance", "1.5"]) == 1
    assert check_regression.main(
        [str(cur), "--history", str(hist), "--tolerance", "3.0"]) == 0


def test_regression_gate_passes_within_noise(tmp_path):
    hist = tmp_path / "h.jsonl"
    for t, us in enumerate((100.0, 102.0, 98.0)):
        trajectory.append_history(_doc(us, t=t), hist)
    verdicts = check_regression.check([_doc(110.0, t=9)], hist,
                                      tolerance=1.5)
    assert verdicts[0]["status"] == "ok"
    assert verdicts[0]["ratio"] == pytest.approx(1.1)


def test_regression_gate_skips_unmatched_platform(tmp_path):
    hist = tmp_path / "h.jsonl"
    trajectory.append_history(_doc(100.0, backend="tpu"), hist)
    verdicts = check_regression.check([_doc(500.0)], hist, tolerance=1.5)
    assert verdicts[0]["status"] == "no-baseline"


def test_bless_resets_baseline(tmp_path):
    hist = tmp_path / "h.jsonl"
    trajectory.append_history(_doc(100.0, t=1), hist)
    # 3x slower: gated...
    with pytest.raises(check_regression.RegressionError):
        check_regression.check([_doc(300.0, t=2)], hist, tolerance=1.5)
    # ...until blessed (accepted intentional regression)
    trajectory.append_bless(hist, note="slower but exact", unix_time=3)
    verdicts = check_regression.check([_doc(300.0, t=4)], hist,
                                      tolerance=1.5)
    assert verdicts[0]["status"] == "no-baseline"
    # new history accrues after the marker and gates again
    trajectory.append_history(_doc(300.0, t=5), hist)
    with pytest.raises(check_regression.RegressionError):
        check_regression.check([_doc(900.0, t=6)], hist, tolerance=1.5)


def test_scoped_bless_only_covers_named_row(tmp_path):
    hist = tmp_path / "h.jsonl"
    trajectory.append_history(_doc(100.0, row="a", t=1), hist)
    trajectory.append_history(_doc(100.0, row="b", t=1), hist)
    trajectory.append_bless(hist, bench="batched", row="a", unix_time=2)
    recs = trajectory.load_history(hist)
    assert not trajectory.baseline_records(
        recs, "batched", "a", "cpu/x86_64/d1", True)
    assert trajectory.baseline_records(
        recs, "batched", "b", "cpu/x86_64/d1", True)


def test_committed_history_gates_committed_benches():
    """The in-repo BENCH_history.jsonl must cover the committed smoke
    artifacts: every committed row either passes the gate or has a
    matched baseline to compare against at CI tolerance."""
    from benchmarks.common import repo_root_default

    root = repo_root_default()
    hist = root / "BENCH_history.jsonl"
    assert hist.exists()
    docs = [json.loads((root / f).read_text())
            for f in ("BENCH_batched.json", "BENCH_serve.json")]
    verdicts = check_regression.check(docs, hist, tolerance=4.0)
    assert verdicts and all(v["status"] in ("ok", "no-baseline")
                            for v in verdicts)
