"""Block cyclic reduction: log-depth chain solves for the SaP-E reduced
interface system, against the sequential btf/bts chain sweep as oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_to_dense, oscillatory_banded
from repro.core.block_lu import btf_chain, bts_chain
from repro.core.cyclic_reduction import (
    bcr_factor,
    bcr_solve,
    pad_chain,
    pcr_factor,
    pcr_n_levels,
    pcr_solve,
    resolve_reduced_solver,
)
from repro.kernels import ops


def _chain(m, k, r=3, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.normal(size=(m, k, k)), dtype) + 4 * jnp.eye(k, dtype=dtype)
    e = jnp.asarray(rng.normal(size=(m, k, k)) * 0.3, dtype)
    f = jnp.asarray(rng.normal(size=(m, k, k)) * 0.3, dtype)
    b = jnp.asarray(rng.normal(size=(m, k, r)), dtype)
    return d, e, f, b


TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m", [1, 2, 3, 5, 8, 16])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_bcr_matches_chain_sweep(m, k):
    """bcr_factor/bcr_solve == btf_chain/bts_chain for any chain length,
    including non-powers of two (identity padding)."""
    d, e, f, b = _chain(m, k, seed=10 * m + k)
    x_seq = bts_chain(btf_chain(d, e, f), b)
    x_bcr = bcr_solve(bcr_factor(d, e, f), b)
    np.testing.assert_allclose(np.asarray(x_bcr), np.asarray(x_seq), **TOL)


@pytest.mark.parametrize("m", [2, 5, 8])
def test_bcr_interpret_kernels_match_ref(m):
    """The Pallas kernel pair (interpret mode) builds the same factors and
    solution as the pure-jnp reference, through the ops dispatch."""
    k = 4
    d, e, f, b = _chain(m, k, seed=m)
    x_ref = ops.bcr_solve(ops.bcr_factor(d, e, f, impl="jnp"), b, impl="jnp")
    fac_i = ops.bcr_factor(d, e, f, impl="interpret")
    x_int = ops.bcr_solve(fac_i, b, impl="interpret")
    np.testing.assert_allclose(np.asarray(x_int), np.asarray(x_ref), **TOL)
    # factor pytrees are structurally identical across impls
    fac_r = ops.bcr_factor(d, e, f, impl="jnp")
    assert fac_r.m == fac_i.m
    assert len(fac_r.levels) == len(fac_i.levels)
    np.testing.assert_allclose(
        np.asarray(fac_i.root_inv), np.asarray(fac_r.root_inv), **TOL
    )


@pytest.mark.parametrize("m,k,r", [(5, 4, 3), (8, 6, 1), (3, 16, 5), (1, 4, 2)])
def test_bcr_lane_padded_kernels_match_ref(m, k, r):
    """The compiled-path lane padding (small-K blocks embedded into the
    8x128 fp32 tile: identity tail on D, zeros on E/F/RHS) is exact --
    forced on under interpret mode it reproduces the jnp reference, and
    the solution comes back sliced to the original (M, K, R)."""
    from repro.kernels.bcr import bcr_factor_pallas, bcr_solve_pallas

    d, e, f, b = _chain(m, k, r=r, seed=7 * m + k)
    x_ref = bcr_solve(bcr_factor(d, e, f), b)
    fac = bcr_factor_pallas(d, e, f, interpret=True, lane_pad=True)
    kp = fac.root_inv.shape[-1]
    assert kp % 8 == 0 and kp % 128 == 0 and kp >= k  # tile-aligned blocks
    x = bcr_solve_pallas(fac, b, interpret=True)
    assert x.shape == (m, k, r)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref), **TOL)


def test_bcr_lane_pad_noop_when_aligned():
    """Blocks already on the (8, 128) tile are left untouched."""
    from repro.kernels.bcr import bcr_factor_pallas

    d, e, f, b = _chain(4, 128, r=2, seed=0)
    fac = bcr_factor_pallas(d, e, f, interpret=True, lane_pad=True)
    assert fac.root_inv.shape == (128, 128)


@pytest.mark.parametrize("m", [1, 3, 7, 8, 13])
def test_pcr_local_shifts_match_chain_sweep(m):
    """The all-active PCR form (the distributed sweep's algorithm) with
    single-device shifts agrees with the sequential chain sweep -- the
    oracle the sharded variant-E path is tested against."""
    k = 4
    d, e, f, b = _chain(m, k, seed=100 + m)
    x_seq = bts_chain(btf_chain(d, e, f), b)
    dp, ep, fp = pad_chain(d, e, f)
    rows = dp.shape[0]
    pf = pcr_factor(dp, ep, fp, pcr_n_levels(m))
    bp = (
        jnp.concatenate([b, jnp.zeros((rows - m,) + b.shape[1:], b.dtype)])
        if rows != m
        else b
    )
    x_pcr = pcr_solve(pf, bp)[:m]
    np.testing.assert_allclose(np.asarray(x_pcr), np.asarray(x_seq), **TOL)


def test_reduced_solver_policy():
    assert resolve_reduced_solver("chain", 1000) == "chain"
    assert resolve_reduced_solver("bcr", 2) == "bcr"
    assert resolve_reduced_solver("auto", 7) == "chain"
    assert resolve_reduced_solver("auto", 8) == "bcr"
    with pytest.raises(ValueError):
        resolve_reduced_solver("nope", 4)


@pytest.mark.parametrize("reduced_solver", ["chain", "bcr"])
def test_variant_e_same_solution_either_reduced_solver(reduced_solver):
    """Variant E is an exact preconditioner solve either way: both reduced
    solvers converge immediately on the hard d=0.5 regime and agree."""
    n, k, p = 512, 6, 16
    band = jnp.asarray(oscillatory_banded(n, k, d=0.5, seed=1), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(2).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)
    fac = factor(
        plan_banded(
            band,
            SaPOptions(p=p, variant="E", tol=1e-5, maxiter=50,
                       reduced_solver=reduced_solver),
        )
    )
    assert fac.pc.reduced_solver == reduced_solver
    res = fac.solve(b)
    assert bool(res.converged)
    assert float(res.iterations) <= 3.0
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    assert err < 1e-2


def test_solver_config_maps_to_sap_options():
    """The config-registry knobs reach the lifecycle API: the exact()
    workload preset factors as variant E with the configured chain solver."""
    from repro.configs.sap_solver import exact

    cfg = exact()
    opts = cfg.to_sap_options(p=16)
    assert (opts.variant, opts.reduced_solver) == ("E", "auto")
    band = jnp.asarray(oscillatory_banded(512, 6, d=cfg.d, seed=3), jnp.float32)
    fac = factor(plan_banded(band, opts))
    assert fac.variant == "E"
    assert fac.pc.reduced_solver == "bcr"  # 15 interfaces -> auto = bcr


def test_reduced_solver_choice_in_info():
    """The resolved choice rides the preconditioner pytree and the
    one-shot info dict."""
    from repro.core import solve_banded

    n, k = 512, 6
    band = jnp.asarray(oscillatory_banded(n, k, d=0.5, seed=1), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    b = jnp.asarray(dense @ np.ones(n), jnp.float32)
    sol = solve_banded(band, b, SaPOptions(p=16, variant="E", tol=1e-5))
    assert sol.info["reduced_solver"] == "bcr"  # P-1 = 15 >= 8 -> bcr
    sol = solve_banded(band, b, SaPOptions(p=4, variant="E", tol=1e-5))
    assert sol.info["reduced_solver"] == "chain"
    sol = solve_banded(band, b, SaPOptions(p=4, variant="D", tol=1e-5))
    assert sol.info["reduced_solver"] == "none"
