"""Multi-device tests, run in subprocesses with 8 forced host devices
(the in-process suite must keep seeing exactly 1 device)."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def _run(script: str, devices: int = 8, timeout: int = 900, x64: bool = False):
    env = {
        "PYTHONPATH": str(SRC),
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "JAX_PLATFORMS": "cpu",
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
    }
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout, env=env,
    )


DIST_SAP = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.banded import random_banded, band_to_dense
from repro.core.distributed import build_dist_sap, solve_step_fn
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4), ("data", "model"))
n, k = 600, 6
band = random_banded(n, k, d=1.0, seed=5)
A = np.asarray(band_to_dense(jnp.asarray(band)))
xstar = np.random.default_rng(0).normal(size=n)
b = A @ xstar
for variant in ("C", "D", "E"):
    dsap = build_dist_sap(mesh, n, k, variant=variant, p_per_device=2)
    band_p, b_p, parts = dsap.shard_band(band, b)
    step = solve_step_fn(dsap, tol=1e-6, maxiter=300)
    with mesh:
        res = jax.jit(step)(
            band_p.astype(jnp.float32), b_p.astype(jnp.float32),
            parts["d"], parts["e"], parts["f"], parts["b_next"], parts["c_prev"])
    err = np.linalg.norm(np.asarray(res.x)[:n] - xstar) / np.linalg.norm(xstar)
    assert err < 1e-4, (variant, err)
    assert bool(res.converged), variant
    assert float(res.resnorm) <= 1e-6, (variant, float(res.resnorm))
    print(f"{variant}:{float(res.iterations)}:{err:.2e}")
print("DIST_SAP_OK")
"""


def test_distributed_sap_solver_matches_dense():
    proc = _run(DIST_SAP)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DIST_SAP_OK" in proc.stdout
    # coupled variant must use fewer iterations than decoupled
    lines = dict(
        (ln.split(":")[0], float(ln.split(":")[1]))
        for ln in proc.stdout.splitlines()
        if ln.startswith(("C:", "D:"))
    )
    assert lines["C"] <= lines["D"]


DIST_E_F64 = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_to_dense, oscillatory_banded
from repro.core.distributed import build_dist_sap, solve_step_fn
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))
n, k = 600, 6
band = oscillatory_banded(n, k, d=0.5, seed=0)  # non-decaying spikes
A = np.asarray(band_to_dense(jnp.asarray(band)))
xstar = np.random.default_rng(0).normal(size=n)
b = A @ xstar

# sharded: "auto" estimates d from shard-local rows and must pick E
dsap = build_dist_sap(mesh, n, k, variant="auto", p_per_device=2, band=band)
assert dsap.variant == "E", dsap.variant
assert abs(dsap.d_factor - 0.5) < 1e-6, dsap.d_factor
band_p, b_p, parts = dsap.shard_band(band, b)
step = solve_step_fn(dsap, tol=1e-8, maxiter=100)
with mesh:
    res = jax.jit(step)(band_p, b_p, parts["d"].astype(jnp.float64),
                        parts["e"].astype(jnp.float64),
                        parts["f"].astype(jnp.float64),
                        parts["b_next"].astype(jnp.float64),
                        parts["c_prev"].astype(jnp.float64))
assert bool(res.converged), (float(res.iterations), float(res.resnorm))
assert float(res.resnorm) <= 1e-8
x_dist = np.asarray(res.x)[:n]

# single-device exact reference at the same partition count
fac = factor(plan_banded(jnp.asarray(band),
                         SaPOptions(p=16, variant="E", tol=1e-8, maxiter=100,
                                    precond_dtype="float64")))
ref = fac.solve(jnp.asarray(b))
assert bool(ref.converged)
x_ref = np.asarray(ref.x)

err_x = np.linalg.norm(x_dist - x_ref) / np.linalg.norm(x_ref)
err_star = np.linalg.norm(x_dist - xstar) / np.linalg.norm(xstar)
assert err_x < 1e-6, err_x
assert err_star < 1e-6, err_star
assert abs(float(res.iterations) - float(ref.iterations)) <= 2.0
print(f"E_dist:{float(res.iterations)}:{err_x:.2e}:{err_star:.2e}")
print("DIST_E_F64_OK")
"""


def test_distributed_exact_variant_f64_agrees_with_single_device():
    """Acceptance: sharded variant E (distributed cyclic reduction) hits
    1e-8 in f64 on the d=0.5 oscillatory regime where truncated C stalls,
    and matches the single-device exact factorization."""
    proc = _run(DIST_E_F64, x64=True)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DIST_E_F64_OK" in proc.stdout


DIST_TRAIN = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import get_family
from repro import optim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = get_config("stablelm-1.6b", reduced=True)
fam = get_family(cfg)
params = fam.init(cfg, jax.random.PRNGKey(0))
pspecs = fam.param_pspecs(cfg, mesh)
shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P))
params_sh = jax.device_put(params, shard)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)}

def loss_fn(p, b):
    l, _ = fam.loss(cfg, p, b)
    return l

with mesh:
    l_sh = jax.jit(loss_fn)(params_sh, batch)
l_local = loss_fn(params, batch)
assert abs(float(l_sh) - float(l_local)) < 1e-3, (float(l_sh), float(l_local))
print("DIST_TRAIN_OK")
"""


def test_sharded_loss_matches_single_device():
    proc = _run(DIST_TRAIN)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DIST_TRAIN_OK" in proc.stdout


@pytest.mark.parametrize("mesh_flag", ["", "--multi-pod"])
def test_dryrun_cell_compiles_on_test_mesh(mesh_flag, tmp_path):
    """End-to-end dryrun driver on the scaled-down mesh (8 devices)."""
    out = tmp_path / "cell.json"
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", "stablelm-1.6b", "--shape", "decode_32k",
        "--out", str(out),
    ] + ([mesh_flag] if mesh_flag else [])
    env = {
        "PYTHONPATH": str(SRC),
        "REPRO_DRYRUN_DEVICES": "8",
        "JAX_PLATFORMS": "cpu",
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
    }
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    row = json.loads(out.read_text())
    assert row["roofline"]["flops"] > 0
    assert row["roofline"]["bottleneck"] in ("compute", "memory", "collective")


SOLVER_DRYRUN = r"""
import sys
sys.argv = ["dryrun", "--arch", "sap-solver", "--shape", "dense_200k"]
from repro.launch import dryrun
import json
row = dryrun.lower_solver_cell("dense_200k", False, type("A", (), {
    "variant": "C", "p_per_device": 1, "save_hlo": None,
    "precond_dtype": "float32"})())
assert row["roofline"]["coll_bytes"] > 0  # ppermutes present
print("SOLVER_DRYRUN_OK")
"""


def test_solver_dryrun_has_neighbor_collectives():
    proc = _run(SOLVER_DRYRUN)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SOLVER_DRYRUN_OK" in proc.stdout
