"""Float64 reference validation, run in a subprocess (x64 flag is global).

Asserts the machine-precision claims the f32 in-process tests cannot:
LU/spike algebra to ~1e-12, SaP-C == near-exact solve at d >= 1.
"""

import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"

SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import SaPOptions, solve_banded
from repro.core.banded import band_to_block_tridiag, block_tridiag_to_dense, random_banded
from repro.core.block_lu import btf_ref, bts_ref
from repro.core.spike import build_preconditioner

# block LU at f64: machine precision
band = jnp.asarray(random_banded(96, 6, d=1.0, seed=0))
bt = band_to_block_tridiag(band, 6, 4)
fac = btf_ref(bt.d, bt.e, bt.f)
rhs = jnp.asarray(np.random.default_rng(0).normal(size=(bt.p, bt.m, bt.k, 2)))
x = bts_ref(fac, rhs)
dense = np.asarray(block_tridiag_to_dense(bt))
ni = bt.m * bt.k
for i in range(4):
    ai = dense[i*ni:(i+1)*ni, i*ni:(i+1)*ni]
    r = np.abs(ai @ np.asarray(x[i]).reshape(ni,2) - np.asarray(rhs[i]).reshape(ni,2)).max()
    assert r < 1e-11, f"block LU residual {r}"

# SaP-C preconditioner ~= A^{-1} at d=1.2
pc = build_preconditioner(bt, "C", precond_dtype=jnp.float64)
r = np.random.default_rng(1).normal(size=bt.n_pad)
z = np.asarray(pc.apply(jnp.asarray(r)))
rel = np.linalg.norm(dense @ z - r)/np.linalg.norm(r)
assert rel < 5e-2, f"precond residual {rel}"

# full solve to 1e-12
band = jnp.asarray(random_banded(500, 8, d=1.0, seed=42))
from repro.core.banded import band_to_dense
A = np.asarray(band_to_dense(band))
xstar = np.random.default_rng(2).normal(size=500)
sol = solve_banded(band, jnp.asarray(A @ xstar),
                   SaPOptions(p=8, variant="C", tol=1e-12, precond_dtype="float64"))
err = np.linalg.norm(np.asarray(sol.x) - xstar)/np.linalg.norm(xstar)
assert sol.converged and err < 1e-10, f"solve err {err} it {sol.iterations}"
print("F64_REFERENCE_OK")
"""


def test_f64_reference_suite():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "F64_REFERENCE_OK" in proc.stdout
