"""Fused factor+spike megakernel: parity vs the kernel sequence.

The fused pass (repro.kernels.fused_spike + its scan oracle in
repro.core.block_lu) must be *exactly* the algorithm the btf -> UL-btf ->
bts kernel sequence runs:

  * ``sinv`` / ``l`` / ``v_bot`` / ``w_top`` are the same recurrences in
    the same operation order -> bit-identical to the sequence.
  * ``v_top`` / ``w_bot`` are computed by forward carries instead of
    whole-spike back-substitution -> algebraically equal, compared with
    a float32 tolerance.

One deliberate shape quirk: at M = 1 the scan in ``btf_ref`` produces an
*empty* ``l`` of shape (P, 0, K, K), while the fused paths always emit the
explicit zero block (P, 1, K, K) the Pallas kernel writes at j = 0.  Both
are inert in every solve (``l[1:]`` is empty either way), so the parity
checks compare ``l`` only for M > 1.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.banded import band_to_block_tridiag, random_banded
from repro.core.batched import pad_band_to
from repro.core.block_lu import (
    btf_ref,
    btf_ul_ref,
    bts_ref,
    fused_factor_spike_ref,
    pad_couplings,
)
from repro.core.spike import build_preconditioner, resolve_fused
from repro.kernels import ops


def _chain(rng, p, m, k, dtype=jnp.float32):
    """Well-conditioned block-tridiag chain + off-partition couplings."""
    r = lambda *s: jnp.asarray(rng.normal(size=s), dtype)
    d = r(p, m, k, k) + 4 * jnp.eye(k, dtype=dtype)
    e = r(p, m, k, k) * 0.3
    f = r(p, m, k, k) * 0.3
    b_cpl = r(p - 1, k, k) * 0.3
    c_cpl = r(p - 1, k, k) * 0.3
    return d, e, f, b_cpl, c_cpl


def _sequence_oracle(d, e, f, b_cpl, c_cpl):
    """The kernel-sequence baseline: btf + UL-btf + whole-spike solves."""
    p, m, k, _ = d.shape
    lu = btf_ref(d, e, f)
    v_bot = lu.sinv[:-1, -1] @ b_cpl
    ul = btf_ul_ref(d, e, f)
    w_top = (ul.sinv[1:, -1] @ c_cpl[..., ::-1, :])[..., ::-1, :]
    rhs_b = jnp.zeros((p, m, k, k), d.dtype).at[:-1, -1].set(b_cpl)
    v_top = bts_ref(lu, rhs_b)[:-1, 0]
    rhs_c = jnp.zeros((p, m, k, k), d.dtype).at[1:, 0].set(c_cpl)
    w_bot = bts_ref(lu, rhs_c)[1:, -1]
    return lu, v_bot, v_top, w_top, w_bot


def _assert_corner_parity(fs, d, e, f, b_cpl, c_cpl):
    lu, v_bot, v_top, w_top, w_bot = _sequence_oracle(d, e, f, b_cpl, c_cpl)
    m = d.shape[1]
    # same recurrence, same op order -> bit-identical
    np.testing.assert_array_equal(np.asarray(fs.lu.sinv), np.asarray(lu.sinv))
    if m > 1:
        np.testing.assert_array_equal(np.asarray(fs.lu.l), np.asarray(lu.l))
    np.testing.assert_array_equal(np.asarray(fs.v_bot), np.asarray(v_bot))
    np.testing.assert_array_equal(np.asarray(fs.w_top), np.asarray(w_top))
    # forward carries vs back-substitution -> f32-allclose
    np.testing.assert_allclose(
        np.asarray(fs.v_top), np.asarray(v_top), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(fs.w_bot), np.asarray(w_bot), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# reference (scan) formulation vs the kernel sequence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "p,m,k",
    [(2, 1, 3), (2, 4, 8), (3, 5, 3), (4, 3, 4), (5, 2, 2), (3, 7, 5)],
)
def test_fused_ref_matches_sequence(p, m, k):
    """Non-pow2 grids included; M = 1 exercises the init-only path."""
    rng = np.random.default_rng(p * 100 + m * 10 + k)
    d, e, f, b_cpl, c_cpl = _chain(rng, p, m, k)
    fs = fused_factor_spike_ref(d, e, f, b_cpl, c_cpl)
    assert fs.v_bot.shape == (p - 1, k, k)
    assert fs.w_top.shape == (p - 1, k, k)
    _assert_corner_parity(fs, d, e, f, b_cpl, c_cpl)


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode) vs the scan reference: bit-level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,m,k", [(2, 3, 4), (3, 5, 3), (2, 4, 8), (4, 1, 4)])
def test_fused_kernel_interpret_bit_parity(p, m, k):
    rng = np.random.default_rng(7)
    d, e, f, b_cpl, c_cpl = _chain(rng, p, m, k)
    fr = ops.fused_factor_spike(d, e, f, b_cpl, c_cpl, impl="jnp")
    fk = ops.fused_factor_spike(d, e, f, b_cpl, c_cpl, impl="interpret")
    for name in ("v_bot", "v_top", "w_top", "w_bot"):
        np.testing.assert_array_equal(
            np.asarray(getattr(fr, name)), np.asarray(getattr(fk, name)),
            err_msg=name)
    np.testing.assert_array_equal(
        np.asarray(fr.lu.sinv), np.asarray(fk.lu.sinv))
    np.testing.assert_array_equal(np.asarray(fr.lu.l), np.asarray(fk.lu.l))


def test_fused_kernel_matches_sequence_end_to_end():
    """interpret-mode kernel output vs the btf/bts sequence directly."""
    rng = np.random.default_rng(11)
    d, e, f, b_cpl, c_cpl = _chain(rng, 4, 4, 8)
    fk = ops.fused_factor_spike(d, e, f, b_cpl, c_cpl, impl="interpret")
    _assert_corner_parity(fk, d, e, f, b_cpl, c_cpl)


# ---------------------------------------------------------------------------
# batched (5-dim) dispatch: folded grid == per-system loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["jnp", "interpret"])
def test_fused_batched_fold_matches_per_system(impl):
    s, p, m, k = 3, 4, 3, 4
    rng = np.random.default_rng(13)
    ds, es, fs_, bs, cs = [], [], [], [], []
    for _ in range(s):
        d, e, f, b_cpl, c_cpl = _chain(rng, p, m, k)
        ds.append(d); es.append(e); fs_.append(f)
        bs.append(b_cpl); cs.append(c_cpl)
    D, E, F = jnp.stack(ds), jnp.stack(es), jnp.stack(fs_)
    B, C = jnp.stack(bs), jnp.stack(cs)
    out = ops.fused_factor_spike(D, E, F, B, C, impl=impl)
    assert out.v_bot.shape == (s, p - 1, k, k)
    for i in range(s):
        one = ops.fused_factor_spike(ds[i], es[i], fs_[i], bs[i], cs[i],
                                     impl=impl)
        np.testing.assert_array_equal(
            np.asarray(out.lu.sinv[i]), np.asarray(one.lu.sinv))
        for name in ("v_bot", "v_top", "w_top", "w_bot"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out, name)[i]),
                np.asarray(getattr(one, name)), err_msg=name)


def test_pad_couplings_zero_pad_isolates_fold():
    """Padded coupling slots are exactly zero -> spike corners of the pad
    slots are exactly zero, so the batch fold cannot cross-contaminate."""
    rng = np.random.default_rng(17)
    d, e, f, b_cpl, c_cpl = _chain(rng, 3, 2, 4)
    bq, cq = pad_couplings(b_cpl, c_cpl, 3)
    assert bq.shape == (3, 4, 4) and cq.shape == (3, 4, 4)
    np.testing.assert_array_equal(np.asarray(bq[-1]), 0.0)
    np.testing.assert_array_equal(np.asarray(cq[0]), 0.0)


# ---------------------------------------------------------------------------
# preconditioner / solve level: fused on == fused off
# ---------------------------------------------------------------------------


def test_resolve_fused_policy():
    assert resolve_fused("on", "jnp") is True
    assert resolve_fused(True, "jnp") is True
    assert resolve_fused("off", "pallas") is False
    assert resolve_fused(False, "pallas") is False
    assert resolve_fused(None, "pallas") is False
    assert resolve_fused("auto", "jnp") is False
    assert resolve_fused("auto", "interpret") is False
    assert resolve_fused("auto", "pallas") is True
    with pytest.raises(ValueError):
        resolve_fused("always", "jnp")


@pytest.mark.parametrize("variant", ["C", "E"])
def test_preconditioner_fused_on_off_equivalent(variant):
    band = jnp.asarray(random_banded(96, 3, 1.2, seed=3), jnp.float32)
    bt = band_to_block_tridiag(band, 3, 4)
    p_off = build_preconditioner(bt, variant=variant, fused="off")
    p_on = build_preconditioner(bt, variant=variant, fused="on")
    assert p_off.fused is False and p_on.fused is True
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.normal(size=96), jnp.float32)
    a_off, a_on = p_off.apply(r), p_on.apply(r)
    if variant == "C":
        # the C-ul path consumes only the bit-identical corners
        np.testing.assert_array_equal(np.asarray(a_off), np.asarray(a_on))
    else:
        np.testing.assert_allclose(
            np.asarray(a_off), np.asarray(a_on), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("variant", ["C", "E"])
def test_preconditioner_fused_padded_identity_bucket(variant):
    """Bucket padding (interleaved identity rows) stays exact under the
    fused pass: padded-system corners equal the unpadded system's via the
    structural-zero pivot exemption, same as the sequence path."""
    n, k = 80, 2
    band = np.float32(random_banded(n, k, 1.3, seed=9))
    padded = pad_band_to(jnp.asarray(band), 128, 4)
    bt = band_to_block_tridiag(jnp.asarray(padded), 4, 4)
    p_off = build_preconditioner(bt, variant=variant, fused="off")
    p_on = build_preconditioner(bt, variant=variant, fused="on")
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.normal(size=128), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(p_off.apply(r)), np.asarray(p_on.apply(r)),
        rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("variant", ["C", "E"])
def test_solve_fused_on_off_equivalent(variant):
    from repro.core import SaPOptions, factor, plan_banded
    from repro.core.banded import band_matvec

    band = jnp.asarray(random_banded(160, 4, 1.2, seed=21), jnp.float32)
    x = np.random.default_rng(2).normal(size=160)
    b = band_matvec(band, jnp.asarray(x, jnp.float32))
    res = {}
    for fused in ("off", "on"):
        opts = SaPOptions(p=4, variant=variant, tol=1e-6, maxiter=200,
                          fused_factor=fused)
        fac = factor(plan_banded(band, opts))
        assert fac.pc.fused is (fused == "on")
        res[fused] = fac.solve(b)
        assert bool(res[fused].converged)
        assert float(res[fused].true_resnorm) < 1e-5
    np.testing.assert_allclose(
        np.asarray(res["off"].x), np.asarray(res["on"].x),
        rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# property test (hypothesis, optional dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # optional dependency: CI installs it, the image may not
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=15, print_blob=True)
    @given(
        p=st.integers(min_value=2, max_value=5),
        m=st.integers(min_value=1, max_value=6),
        k=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_fused_ref_parity_property(p, m, k, seed):
        """For any chain shape: exact parity on the LU half, f32-allclose
        on the carried spike corners (jnp ref vs kernel sequence)."""
        rng = np.random.default_rng(seed)
        d, e, f, b_cpl, c_cpl = _chain(rng, p, m, k)
        fs = fused_factor_spike_ref(d, e, f, b_cpl, c_cpl)
        _assert_corner_parity(fs, d, e, f, b_cpl, c_cpl)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_ref_parity_property():
        pass
