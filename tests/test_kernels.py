"""Pallas kernel validation: interpret-mode vs pure-jnp oracle, shape/dtype
sweeps (per-kernel allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# block-tridiag factor / solve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,m,k", [(1, 2, 4), (3, 5, 8), (2, 4, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_btf_matches_ref(p, m, k, dtype):
    """The kernel computes in f32 and stores in the input dtype (mixed
    precision, paper Sec 3.1) -- so the oracle is the f32 reference cast
    to the storage dtype."""
    rng = np.random.default_rng(0)
    d = _rand(rng, (p, m, k, k), dtype) + 4 * jnp.eye(k, dtype=dtype)
    e = _rand(rng, (p, m, k, k), dtype) * jnp.asarray(0.3, dtype)
    f = _rand(rng, (p, m, k, k), dtype) * jnp.asarray(0.3, dtype)
    fr = ref.btf_ref(d.astype(jnp.float32), e.astype(jnp.float32),
                     f.astype(jnp.float32))
    fp = ops.block_tridiag_factor(d, e, f, impl="interpret")
    tol = dict(rtol=1e-5, atol=1e-6) if dtype == jnp.float32 else dict(
        rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(fr.sinv, np.float32), np.asarray(fp.sinv, np.float32),
        **tol)
    np.testing.assert_allclose(
        np.asarray(fr.l, np.float32), np.asarray(fp.l, np.float32), **tol)


@pytest.mark.parametrize("p,m,k,r", [(2, 3, 4, 1), (1, 6, 8, 8), (3, 2, 16, 4)])
def test_bts_matches_ref(p, m, k, r):
    rng = np.random.default_rng(1)
    d = _rand(rng, (p, m, k, k)) + 4 * jnp.eye(k)
    e = _rand(rng, (p, m, k, k)) * 0.3
    f = _rand(rng, (p, m, k, k)) * 0.3
    fac = ref.btf_ref(d, e, f)
    b = _rand(rng, (p, m, k, r))
    xr = ref.bts_ref(fac, b)
    xp = ops.block_tridiag_solve(fac, b, impl="interpret")
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xp), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("s,p,m,k,r", [(2, 3, 4, 4, 2), (4, 1, 3, 8, 1)])
def test_batched_btf_bts_fold_matches_per_system(s, p, m, k, r):
    """5-dim inputs (a leading system axis) fold the batch into the
    parallel partition grid axis: same math as looping the systems, for
    both the jnp reference and the interpret-mode kernels."""
    rng = np.random.default_rng(5)
    d = _rand(rng, (s, p, m, k, k)) + 4 * jnp.eye(k)
    e = _rand(rng, (s, p, m, k, k)) * 0.3
    f = _rand(rng, (s, p, m, k, k)) * 0.3
    b = _rand(rng, (s, p, m, k, r))
    for impl in ("jnp", "interpret"):
        fac = ops.block_tridiag_factor(d, e, f, impl=impl)
        assert fac.sinv.shape == (s, p, m, k, k)
        x = ops.block_tridiag_solve(fac, b, impl=impl)
        assert x.shape == b.shape
        for i in range(s):
            fac_i = ops.block_tridiag_factor(d[i], e[i], f[i], impl=impl)
            np.testing.assert_allclose(
                np.asarray(fac.sinv[i]), np.asarray(fac_i.sinv),
                rtol=1e-5, atol=1e-6)
            x_i = ops.block_tridiag_solve(fac_i, b[i], impl=impl)
            np.testing.assert_allclose(
                np.asarray(x[i]), np.asarray(x_i), rtol=1e-5, atol=1e-6)


def test_batched_chain_ops_ride_partition_axis():
    """(S, M, K, K) chain batches reuse the partition grid axis."""
    rng = np.random.default_rng(6)
    s, m, k, r = 3, 5, 4, 2
    d = _rand(rng, (s, m, k, k)) + 4 * jnp.eye(k)
    e = _rand(rng, (s, m, k, k)) * 0.3
    f = _rand(rng, (s, m, k, k)) * 0.3
    b = _rand(rng, (s, m, k, r))
    fac = ops.block_tridiag_factor_chain(d, e, f, impl="interpret")
    x = ops.block_tridiag_solve_chain(fac, b, impl="interpret")
    for i in range(s):
        fac_i = ops.block_tridiag_factor_chain(d[i], e[i], f[i],
                                               impl="interpret")
        x_i = ops.block_tridiag_solve_chain(fac_i, b[i], impl="interpret")
        np.testing.assert_allclose(np.asarray(x[i]), np.asarray(x_i),
                                   rtol=1e-5, atol=1e-6)


def test_btf_pivot_boost_in_kernel():
    # a singular diagonal block must not produce NaN thanks to boosting
    d = jnp.zeros((1, 2, 4, 4)).at[:, :, 0, 0].set(1.0)
    e = jnp.zeros_like(d)
    f = jnp.zeros_like(d)
    fac = ops.block_tridiag_factor(d, e, f, boost_eps=1e-6, impl="interpret")
    assert bool(jnp.all(jnp.isfinite(fac.sinv)))


# ---------------------------------------------------------------------------
# WKV6 chunked kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,t,dd,chunk", [(1, 1, 32, 8, 8), (2, 3, 64, 16, 16),
                                            (1, 2, 96, 8, 32)])
def test_wkv6_kernel_vs_sequential(b, h, t, dd, chunk, dtype):
    if dtype == jnp.bfloat16 and t > 64:
        pytest.skip("bf16 cumsum drift beyond tolerance at long T")
    rng = np.random.default_rng(2)
    r = _rand(rng, (b, h, t, dd))
    k = _rand(rng, (b, h, t, dd))
    v = _rand(rng, (b, h, t, dd))
    logw = -jnp.exp(_rand(rng, (b, h, t, dd)) * 0.5)
    u = _rand(rng, (h, dd))
    s0 = _rand(rng, (b, h, dd, dd)) * 0.1
    o_ref, s_ref = ref.wkv6_ref(r, k, v, logw, u, s0)
    if dtype == jnp.bfloat16:
        r, k, v = (x.astype(dtype) for x in (r, k, v))
    o_pl, s_pl = ops.wkv6(r, k, v, logw, u, s0, chunk=chunk, impl="interpret")
    tol = 2e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o_pl, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(s_ref, np.float32),
                               np.asarray(s_pl, np.float32), rtol=tol,
                               atol=tol)


def test_wkv6_strong_decay_no_overflow():
    """Strong decay (log w << 0) must stay finite: the chunked form only
    exponentiates non-positive numbers (see kernel docstring)."""
    rng = np.random.default_rng(3)
    b, h, t, dd = 1, 1, 64, 8
    r = _rand(rng, (b, h, t, dd))
    k = _rand(rng, (b, h, t, dd))
    v = _rand(rng, (b, h, t, dd))
    logw = jnp.full((b, h, t, dd), -30.0)
    u = _rand(rng, (h, dd))
    s0 = jnp.zeros((b, h, dd, dd))
    o, s = ops.wkv6(r, k, v, logw, u, s0, chunk=16, impl="interpret")
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(s)))


# ---------------------------------------------------------------------------
# SSD chunked kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,t,n,pd,chunk", [(1, 1, 32, 4, 8, 8),
                                              (2, 2, 64, 8, 16, 16),
                                              (1, 3, 96, 16, 8, 32)])
def test_ssd_kernel_vs_sequential(b, h, t, n, pd, chunk):
    rng = np.random.default_rng(4)
    x = _rand(rng, (b, h, t, pd))
    bm = _rand(rng, (b, h, t, n))
    cm = _rand(rng, (b, h, t, n))
    la = -jnp.exp(_rand(rng, (b, h, t)) * 0.5)
    s0 = _rand(rng, (b, h, n, pd)) * 0.1
    y_ref, s_ref = ref.ssd_ref(x, bm, cm, la, s0)
    y_pl, s_pl = ops.ssd(x, bm, cm, la, s0, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pl), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pl), rtol=2e-4,
                               atol=2e-4)


def test_ssd_state_carry_across_calls():
    """Chunked call over [0:T/2] then [T/2:T] == one call over [0:T]."""
    rng = np.random.default_rng(5)
    b, h, t, n, pd = 1, 2, 64, 8, 8
    x = _rand(rng, (b, h, t, pd))
    bm = _rand(rng, (b, h, t, n))
    cm = _rand(rng, (b, h, t, n))
    la = -jnp.exp(_rand(rng, (b, h, t)) * 0.5)
    s0 = jnp.zeros((b, h, n, pd))
    y_full, s_full = ops.ssd(x, bm, cm, la, s0, chunk=16, impl="jnp")
    y1, s1 = ops.ssd(x[:, :, :32], bm[:, :, :32], cm[:, :, :32], la[:, :, :32],
                     s0, chunk=16, impl="jnp")
    y2, s2 = ops.ssd(x[:, :, 32:], bm[:, :, 32:], cm[:, :, 32:], la[:, :, 32:],
                     s1, chunk=16, impl="jnp")
    np.testing.assert_allclose(np.asarray(y_full),
                               np.concatenate([y1, y2], axis=2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-4,
                               atol=1e-5)


def test_default_impl_is_jnp_on_cpu():
    assert ops.default_impl() == "jnp"


# ---------------------------------------------------------------------------
# Flash attention kernel (beyond-paper; see EXPERIMENTS.md section Perf)
# ---------------------------------------------------------------------------


def _dense_attn(q, k, v, causal, window):
    b, hq, tq, d = q.shape
    hk = k.shape[1]
    g = hq // hk
    qg = q.reshape(b, hk, g, tq, d)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qg, k) / np.sqrt(d)
    qp = jnp.arange(tq)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    m = jnp.ones((tq, k.shape[2]), bool)
    if causal:
        m &= qp >= kp
    if window:
        m &= qp - kp < window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgts,bhsd->bhgtd", p, v).reshape(b, hq, tq, d)


@pytest.mark.parametrize(
    "b,hq,hk,t,d,causal,window",
    [
        (1, 2, 2, 128, 16, True, None),
        (2, 4, 2, 128, 32, True, None),  # GQA
        (1, 4, 1, 256, 16, True, 64),  # GQA + sliding window
        (1, 2, 2, 128, 16, False, None),  # bidirectional (encoder)
    ],
)
def test_flash_attention_kernel_vs_dense(b, hq, hk, t, d, causal, window):
    from repro.kernels.flash_attn import flash_attention_pallas

    rng = np.random.default_rng(7)
    q = _rand(rng, (b, hq, t, d))
    k = _rand(rng, (b, hk, t, d))
    v = _rand(rng, (b, hk, t, d))
    truth = _dense_attn(q, k, v, causal, window)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(truth), np.asarray(out), rtol=2e-4,
                               atol=2e-5)


def test_flash_ref_no_nan_under_window_blocks():
    """Regression: -inf masking produced NaN for fully-masked (row, block)
    pairs; the finite NEG_INF formulation must not."""
    from repro.models.layers import flash_attention

    rng = np.random.default_rng(8)
    q = _rand(rng, (1, 2, 256, 16))
    k = _rand(rng, (1, 2, 256, 16))
    v = _rand(rng, (1, 2, 256, 16))
    o = flash_attention(q, k, v, causal=True, window=64, block_k=64)
    assert bool(jnp.all(jnp.isfinite(o)))
