"""Unit tests: BiCGStab(2) and CG solvers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.krylov import bicgstab2, cg


def _random_system(n=50, seed=0, spd=False):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, n))
    if spd:
        a = a @ a.T + n * np.eye(n)
    else:
        a = a + n * np.eye(n)  # well-conditioned, nonsymmetric
    x = rng.normal(size=n)
    return a, x, a @ x


def test_bicgstab2_unpreconditioned():
    a, xstar, b = _random_system(seed=1)
    res = bicgstab2(lambda v: jnp.asarray(a) @ v, jnp.asarray(b), tol=1e-8,
                    maxiter=200)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), xstar, rtol=1e-4, atol=1e-5)


def test_bicgstab2_exact_preconditioner_quarter_exit():
    """With M = A^{-1} the solver must exit in <= 1 iteration and must NOT
    poison x (regression test for the MR degeneracy guard)."""
    a, xstar, b = _random_system(seed=2)
    ainv = jnp.asarray(np.linalg.inv(a))
    res = bicgstab2(
        lambda v: jnp.asarray(a) @ v,
        jnp.asarray(b),
        precond=lambda v: ainv @ v,
        tol=1e-5,
        maxiter=50,
    )
    assert bool(res.converged)
    assert float(res.iterations) <= 1.0
    np.testing.assert_allclose(np.asarray(res.x), xstar, rtol=1e-3, atol=1e-4)


def test_bicgstab2_counts_quarters():
    a, xstar, b = _random_system(seed=3)
    ainv = jnp.asarray(np.linalg.inv(a))
    res = bicgstab2(lambda v: jnp.asarray(a) @ v, jnp.asarray(b),
                    precond=lambda v: ainv @ v, tol=1e-5)
    assert float(res.iterations) in (0.0, 0.25, 0.5, 1.0)


def test_cg_spd():
    a, xstar, b = _random_system(seed=4, spd=True)
    res = cg(lambda v: jnp.asarray(a) @ v, jnp.asarray(b), tol=1e-10,
             maxiter=500)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), xstar, rtol=1e-4, atol=1e-5)


def test_cg_jacobi_preconditioner_helps():
    rng = np.random.default_rng(5)
    d = np.abs(rng.normal(size=60)) * 100 + 1
    a = np.diag(d) + rng.normal(size=(60, 60)) * 0.1
    a = (a + a.T) / 2 + 10 * np.eye(60)
    b = rng.normal(size=60)
    plain = cg(lambda v: jnp.asarray(a) @ v, jnp.asarray(b), tol=1e-10,
               maxiter=500)
    jac = cg(
        lambda v: jnp.asarray(a) @ v,
        jnp.asarray(b),
        precond=lambda v: v / jnp.asarray(np.diag(a)),
        tol=1e-10,
        maxiter=500,
    )
    assert bool(jac.converged)
    assert float(jac.iterations) <= float(plain.iterations)


def test_bicgstab2_zero_rhs():
    a, _, _ = _random_system(seed=6)
    res = bicgstab2(lambda v: jnp.asarray(a) @ v, jnp.zeros(50), tol=1e-10)
    assert bool(res.converged)
    assert float(jnp.abs(res.x).max()) == 0.0


def test_bicgstab2_maxiter_respected():
    # nearly singular, no preconditioner, tiny budget
    rng = np.random.default_rng(7)
    a = rng.normal(size=(80, 80))
    b = rng.normal(size=80)
    res = bicgstab2(lambda v: jnp.asarray(a) @ v, jnp.asarray(b), tol=1e-14,
                    maxiter=3)
    assert float(res.iterations) <= 3.0
