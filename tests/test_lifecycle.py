"""Plan/factor/solve lifecycle tests: reuse, pytrees, batching, dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BandedOperator,
    SaPOptions,
    factor,
    plan,
    plan_banded,
    solve_banded,
    solve_sparse,
)
from repro.core.banded import band_to_dense, random_banded, random_rhs
from repro.core.sparse import random_sparse


def _banded_system(n=320, k=5, d=1.0, seed=11, nrhs=None):
    band = jnp.asarray(random_banded(n, k, d=d, seed=seed), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    rng = np.random.default_rng(seed + 1)
    xstar = rng.normal(size=(n,) if nrhs is None else (n, nrhs))
    b = jnp.asarray(dense @ xstar, jnp.float32)
    return band, xstar, b


def _sparse_system(n=300, seed=5, nrhs=None):
    csr = random_sparse(n, avg_nnz_per_row=5.0, d=1.5, shuffle=True, seed=seed)
    dense = csr.to_dense()
    rng = np.random.default_rng(seed + 1)
    xstar = rng.normal(size=(n,) if nrhs is None else (n, nrhs))
    b = dense @ xstar
    return csr, xstar, b


# ---------------------------------------------------------------------------
# factor-once / solve-many agreement with the one-shot wrappers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["C", "D"])
def test_lifecycle_matches_one_shot_banded(variant):
    band, xstar, b = _banded_system()
    opts = SaPOptions(p=4, variant=variant, tol=1e-6, maxiter=300)
    fac = factor(plan_banded(band, opts))
    res = fac.solve(b)
    sol = solve_banded(band, b, opts)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(sol.x), rtol=1e-6)
    assert float(res.iterations) == sol.iterations


def test_lifecycle_matches_one_shot_sparse():
    csr, xstar, b = _sparse_system()
    opts = SaPOptions(p=4, variant="C", tol=1e-8)
    fac = factor(plan(csr, opts))
    res = fac.solve(jnp.asarray(b, jnp.float32))
    sol = solve_sparse(csr, b, opts)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), sol.x, rtol=1e-5, atol=1e-6)
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    assert err < 0.01


def test_solve_many_matches_looped_single_rhs():
    band, xstar, b = _banded_system(nrhs=7)
    fac = factor(plan_banded(band, SaPOptions(p=4, tol=1e-6, maxiter=300)))
    many = fac.solve_many(b)
    assert many.x.shape == b.shape
    assert many.iterations.shape == (7,)
    assert bool(many.converged.all())
    for j in range(7):
        one = fac.solve(b[:, j])
        np.testing.assert_allclose(
            np.asarray(many.x[:, j]), np.asarray(one.x), rtol=1e-5, atol=1e-6
        )


def test_vmapped_solve_matches_solve_many():
    band, xstar, b = _banded_system(nrhs=5)
    fac = factor(plan_banded(band, SaPOptions(p=4, tol=1e-6, maxiter=300)))
    many = fac.solve_many(b)
    vx = jax.vmap(lambda bi: fac.solve(bi).x, in_axes=1, out_axes=1)(b)
    np.testing.assert_allclose(np.asarray(vx), np.asarray(many.x), rtol=1e-6)


# ---------------------------------------------------------------------------
# pytree round-trip through jax.jit / flatten-unflatten
# ---------------------------------------------------------------------------


def test_factorization_pytree_roundtrip():
    csr, xstar, b = _sparse_system()
    fac = factor(plan(csr, SaPOptions(p=4, tol=1e-8)))
    leaves, treedef = jax.tree_util.tree_flatten(fac)
    assert all(isinstance(l, jax.Array) for l in leaves)
    fac2 = jax.tree_util.tree_unflatten(treedef, leaves)
    r1 = fac.solve(jnp.asarray(b, jnp.float32))
    r2 = fac2.solve(jnp.asarray(b, jnp.float32))
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))


def test_factorization_through_jit_and_solve_many_under_jit():
    band, xstar, b16 = _banded_system(nrhs=16)
    fac = factor(plan_banded(band, SaPOptions(p=4, tol=1e-6, maxiter=300)))

    @jax.jit
    def solve_all(f, bb):
        return f.solve_many(bb)

    res = solve_all(fac, b16)
    assert res.x.shape == b16.shape
    assert bool(res.converged.all())
    err = np.abs(np.asarray(res.x) - xstar).max()
    assert err < 1e-3


# ---------------------------------------------------------------------------
# reuse: 16 RHS against one factorization run reorder + block-LU exactly once
# ---------------------------------------------------------------------------


def test_factor_once_for_many_rhs(monkeypatch):
    import repro.core.reorder as reorder_mod
    import repro.core.spike as spike_mod

    counts = {"db": 0, "cm": 0, "btf": 0}
    real_db = reorder_mod.diagonal_boosting
    real_cm = reorder_mod.cuthill_mckee
    real_btf = spike_mod.btf_ref

    def db(*a, **kw):
        counts["db"] += 1
        return real_db(*a, **kw)

    def cm(*a, **kw):
        counts["cm"] += 1
        return real_cm(*a, **kw)

    def btf(*a, **kw):
        counts["btf"] += 1
        return real_btf(*a, **kw)

    monkeypatch.setattr(reorder_mod, "diagonal_boosting", db)
    monkeypatch.setattr(reorder_mod, "cuthill_mckee", cm)
    monkeypatch.setattr(spike_mod, "btf_ref", btf)

    csr, xstar, b = _sparse_system(nrhs=16)
    fac = factor(plan(csr, SaPOptions(p=4, tol=1e-8)))
    res = fac.solve_many(jnp.asarray(b, jnp.float32))
    assert bool(res.converged.all())
    err = np.abs(np.asarray(res.x) - xstar).max()
    assert err < 1e-3
    # the expensive stages ran exactly once, not once per RHS
    assert counts == {"db": 1, "cm": 1, "btf": 1}


# ---------------------------------------------------------------------------
# variant "D" + drop-off path
# ---------------------------------------------------------------------------


def test_lifecycle_variant_d_with_dropoff():
    csr = random_sparse(300, avg_nnz_per_row=6.0, d=2.0, shuffle=True, seed=6)
    xstar = np.asarray(random_rhs(300))
    b = csr.to_dense() @ xstar
    pl = SaPOptions(p=4, variant="D", tol=1e-8, drop_tol=0.02)
    sp = plan(csr, pl)
    assert "k_after_drop" in sp.info
    assert sp.info["k_after_drop"] <= sp.info["k_after_reorder"]
    fac = factor(sp)
    res = fac.solve(jnp.asarray(b, jnp.float32))
    assert bool(res.converged)
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    assert err < 0.01


# ---------------------------------------------------------------------------
# dtype semantics (the solve follows the RHS / the iter_dtype option)
# ---------------------------------------------------------------------------


def test_iteration_dtype_follows_rhs():
    band, xstar, b = _banded_system()
    fac = factor(plan_banded(band, SaPOptions(p=4, tol=1e-4, maxiter=300)))
    assert fac.solve(b).x.dtype == jnp.float32
    assert fac.solve(b.astype(jnp.bfloat16)).x.dtype == jnp.bfloat16


def test_iteration_dtype_option_overrides_rhs():
    band, xstar, b = _banded_system()
    opts = SaPOptions(p=4, tol=1e-4, maxiter=300, iter_dtype="float32")
    fac = factor(plan_banded(band, opts))
    assert fac.solve(b.astype(jnp.bfloat16)).x.dtype == jnp.float32


def test_sparse_rhs_dtype_not_hardcoded():
    """Regression: solve_sparse used to force the RHS to float64 and pick
    the iteration dtype from jax_enable_x64 regardless of the input."""
    csr, xstar, b = _sparse_system()
    fac = factor(plan(csr, SaPOptions(p=4, tol=1e-6)))
    res = fac.solve(jnp.asarray(b, jnp.float32))
    assert res.x.dtype == jnp.float32
    # integer RHS promotes to the canonical float instead of crashing
    res_i = fac.solve(jnp.arange(csr.n, dtype=jnp.int32))
    assert jnp.issubdtype(res_i.x.dtype, jnp.floating)


def test_one_shot_wrappers_emit_deprecation_warning():
    band, xstar, b = _banded_system()
    with pytest.warns(DeprecationWarning, match="solve_banded"):
        solve_banded(band, b, SaPOptions(p=4, tol=1e-4, maxiter=100))
    csr, xstar2, b2 = _sparse_system()
    with pytest.warns(DeprecationWarning, match="solve_sparse"):
        solve_sparse(csr, b2, SaPOptions(p=4, tol=1e-4, maxiter=100))


def test_banded_operator_wrapping():
    band, xstar, b = _banded_system()
    op = BandedOperator.from_band(band)
    assert op.n == band.shape[0] and op.k == (band.shape[1] - 1) // 2
    y = op.matvec(b)
    assert y.shape == b.shape
    fac = factor(plan(op, SaPOptions(p=4, tol=1e-6, maxiter=300)))
    assert bool(fac.solve(b).converged)
