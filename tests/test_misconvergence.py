"""Misconvergence regression suite: the converged-but-wrong solve is dead.

PR 6 left an open item: pow2 bucketing K=3 -> 4 inserted a structurally
zero outer diagonal, `boost_eps` regularized the resulting singular
coupling blocks, and the solver reported ``converged=True`` on the
preconditioned residual while the TRUE residual sat at ~1e-2.  This file
pins the three layers of the fix:

  * the interleaved identity-row K-padding embeds a K-rounded band as an
    exact (permuted) blkdiag(A, I) system -- property-tested across
    variants C/D/E and both generators (run under ``JAX_ENABLE_X64`` in
    CI for the strict oscillatory d<1 cases);
  * ``gj_inverse`` never boosts structurally-zero pivot rows;
  * ``true_resnorm`` is populated on the single, batched, and served
    paths, and the serving guard escalates a converged-but-wrong solve
    instead of returning it.

No test here pins K to the bucket K -- the whole point is that K
rounding no longer needs a workaround.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SaPOptions,
    batch_factor,
    batch_plan,
    bucket_shape,
    factor,
    gj_inverse,
    pad_band_to,
    pad_permutation,
    pad_rhs_to,
    plan_banded,
    solve_banded,
    unpad_solution,
)
from repro.core.banded import (
    band_to_dense,
    oscillatory_banded,
    random_banded,
)
from repro.serve.solver_engine import SolverEngine
from repro.serve.service import AsyncSolverService

X64 = jax.config.jax_enable_x64
FDTYPE = jnp.float64 if X64 else jnp.float32
# the preconditioner runs in f32 by default; under x64 the strict
# tolerances below need the f64 preconditioner as well
PKW = {"precond_dtype": "float64"} if X64 else {}


def _true_res(band, x, b):
    A = np.asarray(band_to_dense(jnp.asarray(band)), np.float64)
    b = np.asarray(b, np.float64)
    return np.linalg.norm(b - A @ np.asarray(x, np.float64)) / np.linalg.norm(b)


def _band(gen, n, k, d, seed):
    if gen == "oscillatory":
        return np.asarray(oscillatory_banded(n, k, d=d, seed=seed), FDTYPE)
    return np.asarray(random_banded(n, k, d=d, seed=seed), FDTYPE)


# ---------------------------------------------------------------------------
# the PR 6 repro, un-pinned
# ---------------------------------------------------------------------------


def test_pr6_repro_oscillatory_k3_pow2_bucket_variant_e():
    """Oscillatory d<1 band, K=3 pow2-bucketed to 4, variant E: converges
    with true_resnorm <= tol (the old code plateaued at ~1e-2)."""
    tol = 1e-10 if X64 else 1e-5
    band = _band("oscillatory", 128, 3, 0.5, seed=0)
    rng = np.random.default_rng(1)
    b = np.asarray(rng.normal(size=128), FDTYPE)
    opts = SaPOptions(p=4, variant="E", tol=tol, maxiter=400, **PKW)
    bpl = batch_plan([band], opts, rounding="pow2")
    assert bpl.k == 4 and bpl.orig_ks == (3,)  # K actually rounded
    bfac = batch_factor(bpl)
    res = bfac.solve_batch(pad_rhs_to(jnp.asarray(b), bpl.n)[None])
    assert bool(np.asarray(res.converged).all())
    (x,) = unpad_solution(res.x, bpl.orig_ns)
    assert _true_res(band, x, b) <= tol
    # and the result object agrees with the from-scratch computation
    assert float(res.true_resnorm[0]) <= tol


# ---------------------------------------------------------------------------
# padding exactness, property-style sweep (C/D/E x generators x shapes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["C", "D", "E"])
@pytest.mark.parametrize(
    "gen,d", [("random", 1.2), ("oscillatory", 0.5)]
)
@pytest.mark.parametrize("n,k,seed", [(96, 3, 0), (130, 6, 1), (200, 5, 2)])
def test_k_and_n_rounded_embedding_is_algebraically_exact(
    variant, gen, d, n, k, seed
):
    """The padded system's exact solution restricts to the unpadded
    system's exact solution -- checked in float64 linear algebra, so this
    is a statement about the *embedding*, not about Krylov accuracy."""
    if variant in ("C", "D") and d < 1:
        pytest.skip("truncated variants are not expected to be exact at d<1")
    band = _band(gen, n, k, d, seed)
    nb, kb, _ = bucket_shape(n, k, 4, "pow2")
    assert nb > n and kb > k  # both axes actually round for these shapes
    padded = pad_band_to(jnp.asarray(band), nb, kb)
    dense = np.asarray(band_to_dense(jnp.asarray(band)), np.float64)
    dense_p = np.asarray(band_to_dense(padded), np.float64)
    rng = np.random.default_rng(seed + 7)
    b = rng.normal(size=n)
    bp = np.zeros(nb)
    perm = pad_permutation(n, k, nb, kb)
    assert perm is not None
    bp[perm[:n]] = b  # RHS in the interleaved frame
    xp = np.linalg.solve(dense_p, bp)
    x = np.linalg.solve(dense, b)
    np.testing.assert_allclose(xp[perm[:n]], x, rtol=1e-9, atol=1e-9)
    # padded slots stay exactly zero: identity rows with zero RHS
    mask = np.ones(nb, bool)
    mask[perm[:n]] = False
    np.testing.assert_array_equal(xp[mask], 0.0)


@pytest.mark.parametrize("variant", ["C", "D", "E"])
def test_solver_matches_unpadded_through_k_rounding(variant):
    """End-to-end: the batched solve through a K-rounding bucket agrees
    with the standalone unpadded solve of each system."""
    d = 1.2  # all three variants converge here; E is also exercised at
    # d<1 by the PR 6 repro test above
    tol = 1e-10 if X64 else 1e-6
    opts = SaPOptions(p=4, variant=variant, tol=tol, maxiter=400, **PKW)
    bands = [_band("random", 96, 3, d, s) for s in range(3)]
    rng = np.random.default_rng(11)
    bs = [np.asarray(rng.normal(size=96), FDTYPE) for _ in bands]
    bpl = batch_plan(bands, opts, rounding="pow2")
    assert bpl.k > 3
    bfac = batch_factor(bpl)
    res = bfac.solve_batch(
        jnp.stack([pad_rhs_to(jnp.asarray(b), bpl.n) for b in bs])
    )
    assert bool(np.asarray(res.converged).all())
    xs = unpad_solution(res.x, bpl.orig_ns)
    for band, b, x in zip(bands, bs, xs):
        solo = factor(plan_banded(jnp.asarray(band), opts)).solve(
            jnp.asarray(b)
        )
        assert _true_res(band, x, b) < 100 * tol
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(solo.x),
            rtol=1e-8 if X64 else 1e-3, atol=1e-8 if X64 else 1e-4,
        )


# ---------------------------------------------------------------------------
# gj_inverse: structural zeros are never boosted
# ---------------------------------------------------------------------------


def test_gj_inverse_identity_on_structurally_zero_rows():
    """A block whose trailing rows/cols are identity-padded inverts to
    the inverse of the live block plus identity slots -- no 1/boost_eps
    garbage in the padded rows."""
    rng = np.random.default_rng(3)
    a_live = rng.normal(size=(3, 3))
    blk = np.zeros((5, 5))
    blk[:3, :3] = a_live
    # structurally zero rows 3, 4 (identity-slot semantics)
    inv = np.asarray(gj_inverse(jnp.asarray(blk, FDTYPE), boost_eps=1e-10))
    np.testing.assert_allclose(
        inv[:3, :3], np.linalg.inv(a_live), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(inv[3:, :3], 0.0)
    np.testing.assert_array_equal(inv[:3, 3:], 0.0)
    np.testing.assert_array_equal(inv[3:, 3:], np.eye(2))
    # numerically small but structurally nonzero pivots still boost
    tiny = jnp.asarray(np.diag([1.0, 1e-30]), FDTYPE)
    inv_t = np.asarray(gj_inverse(tiny, boost_eps=1e-10))
    assert np.isfinite(inv_t).all() and inv_t[1, 1] < 1e12


# ---------------------------------------------------------------------------
# true_resnorm is populated on every path
# ---------------------------------------------------------------------------


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_true_resnorm_on_single_batched_and_served_paths():
    band = _band("random", 128, 3, 1.2, seed=4)
    rng = np.random.default_rng(5)
    b = np.asarray(rng.normal(size=128), FDTYPE)
    opts = SaPOptions(p=4, variant="C", tol=1e-6, maxiter=300)

    res1 = factor(plan_banded(jnp.asarray(band), opts)).solve(  # single
        jnp.asarray(b)
    )
    assert res1.true_resnorm is not None
    assert abs(float(res1.true_resnorm) - _true_res(band, res1.x, b)) < 1e-4
    sol = solve_banded(jnp.asarray(band), jnp.asarray(b), opts)
    assert np.isfinite(sol.true_resnorm)  # convenience-wrapper float field

    bpl = batch_plan([band], opts, rounding="pow2")  # batched
    res = batch_factor(bpl).solve_batch(
        pad_rhs_to(jnp.asarray(b), bpl.n)[None]
    )
    assert res.true_resnorm is not None
    assert np.isfinite(float(res.true_resnorm[0]))

    eng = SolverEngine(opts)  # served
    eng.submit_system(band, b)
    (done,) = eng.step()
    assert np.isfinite(done.result.true_resnorm)
    assert done.result.true_resnorm < 1e-3


# ---------------------------------------------------------------------------
# the serving guard: detect, escalate, never lie
# ---------------------------------------------------------------------------


def _wide_stored_oscillatory(n=128, k_true=3, k_stored=4, seed=1):
    """The user-side twin of the bucketing bug: a K=3 matrix submitted in
    K=4 band storage (exactly-zero outer diagonals).  k == bucket K, so
    no interleave kicks in and the first pass misconverges like PR 6."""
    band3 = np.asarray(oscillatory_banded(n, k_true, d=0.5, seed=seed),
                       FDTYPE)
    wide = np.zeros((n, 2 * k_stored + 1), FDTYPE)
    pad = k_stored - k_true
    wide[:, pad: 2 * k_true + 1 + pad] = band3
    rng = np.random.default_rng(seed + 10)
    x = rng.normal(size=n)
    b = np.asarray(band_to_dense(jnp.asarray(band3)), np.float64) @ x
    return wide, np.asarray(b, FDTYPE)


def test_engine_guard_escalates_converged_but_wrong_solve():
    # the f32 preconditioner in BOTH precision configs: misconvergence is
    # an f32-precond phenomenon, and the guard must catch it there
    tol = 1e-5
    wide, b = _wide_stored_oscillatory()
    eng = SolverEngine(
        SaPOptions(p=4, variant="E", tol=tol, maxiter=400),
        rounding="pow2",
    )
    eng.submit_system(wide, b)
    (done,) = eng.step()
    r = done.result
    assert r.escalated  # the first pass tripped the guard
    assert r.converged
    assert r.true_resnorm <= 10 * tol  # escalation actually fixed it
    assert _true_res(wide, r.x, b) <= 10 * tol
    assert eng.stats["misconverged"] >= 1
    assert eng.stats["escalations"] >= 1


def test_check_true_residual_opt_sets_the_guard():
    """An explicit opts.check_true_residual overrides the 10*tol default:
    a huge guard accepts the first (wrong) pass without escalating."""
    wide, b = _wide_stored_oscillatory()
    eng = SolverEngine(
        SaPOptions(p=4, variant="E", tol=1e-5, maxiter=400,
                   check_true_residual=1e3),
        rounding="pow2",
    )
    eng.submit_system(wide, b)
    (done,) = eng.step()
    assert not done.result.misconverged and not done.result.escalated
    assert eng.stats["escalations"] == 0


def test_service_exports_misconvergence_counters():
    wide, b = _wide_stored_oscillatory(seed=2)
    svc = AsyncSolverService(
        SaPOptions(p=4, variant="E", tol=1e-5, maxiter=400),
        rounding="pow2", start=False,
    )
    fut = svc.submit(wide, b)
    svc.drain_once()
    out = fut.result(timeout=0)
    assert out.escalated and out.converged
    snap = svc.snapshot()
    assert snap["counters"]["misconverged_total"] >= 1
    assert snap["counters"]["escalations"] >= 1
    svc.close()
