"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, output shapes + no NaNs; decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, get_config
from repro.models import get_family
from repro.models.api import ShapeSpec

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab)}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            RNG, (b, cfg.n_patches, cfg.d_model), cfg.cdtype
        )
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            RNG, (b, cfg.enc_seq, cfg.d_model), cfg.cdtype
        )
    return batch


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    batch = _batch(cfg)

    def loss_fn(p):
        l, m = fam.loss(cfg, p, batch)
        return l

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), arch
    gn = optim.global_norm(grads)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0, arch
    # one optimizer step moves the loss
    opt_state = optim.init(params)
    p2, _, _ = optim.apply_updates(
        optim.AdamWConfig(lr=1e-3, warmup_steps=0), params, grads, opt_state
    )
    l2, _ = fam.loss(cfg, p2, batch)
    assert bool(jnp.isfinite(l2))


@pytest.mark.parametrize("arch", list(ARCHS))
def test_smoke_decode_steps(arch):
    cfg = get_config(arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    cache = fam.init_cache(cfg, 2, 64)
    step = jax.jit(lambda p, c, t: fam.decode_step(cfg, p, c, t))
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "starcoder2-15b"])
def test_decode_matches_teacher_forced_forward(arch):
    """Cached decode must reproduce the full forward logits step by step."""
    cfg = get_config(arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    s = 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    full_logits, _ = fam.forward(cfg, params, tokens)
    full_logits = np.asarray(full_logits[..., : cfg.vocab], np.float32)
    cache = fam.init_cache(cfg, 2, 64)
    step = jax.jit(lambda p, c, t: fam.decode_step(cfg, p, c, t))
    for t in range(s):
        logits, cache = step(params, cache, tokens[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), full_logits[:, t],
            rtol=5e-2, atol=5e-3,
        )


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-2.7b"])
def test_recurrent_decode_matches_forward_state(arch):
    """SSM/RWKV: sequential decode state == chunked-forward state."""
    cfg = get_config(arch, reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    s = 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, s), 0, cfg.vocab)
    full_logits, _ = fam.forward(cfg, params, tokens)
    full_logits = np.asarray(full_logits[..., : cfg.vocab], np.float32)
    cache = fam.init_cache(cfg, 2, 64)
    step = jax.jit(lambda p, c, t: fam.decode_step(cfg, p, c, t))
    for t in range(s):
        logits, cache = step(params, cache, tokens[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), full_logits[:, t],
            rtol=5e-2, atol=5e-3,
        )


def test_sliding_window_masks_distant_tokens():
    """One window-32 layer: token 47 must not see token 0 (with stacked
    layers the receptive field compounds to n_layers * window)."""
    import dataclasses

    cfg = dataclasses.replace(
        get_config("starcoder2-15b", reduced=True), n_layers=1
    )
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    rng = jax.random.PRNGKey(3)
    t1 = jax.random.randint(rng, (1, 48), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab)  # differ only at pos 0
    l1, _ = fam.forward(cfg, params, t1)
    l2, _ = fam.forward(cfg, params, t2)
    # last position is > window away from position 0
    np.testing.assert_allclose(
        np.asarray(l1[0, -1], np.float32), np.asarray(l2[0, -1], np.float32),
        rtol=1e-4, atol=1e-5,
    )
    # but an early in-window position must differ
    assert not np.allclose(
        np.asarray(l1[0, 1], np.float32), np.asarray(l2[0, 1], np.float32)
    )


def test_moe_router_balances_under_uniform_tokens():
    cfg = get_config("deepseek-moe-16b", reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    batch = _batch(cfg, b=4, s=64)
    _, metrics = fam.loss(cfg, params, batch)
    assert float(metrics["aux"]) >= 0.0


def test_vlm_patches_change_text_logits():
    cfg = get_config("phi-3-vision-4.2b", reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, RNG)
    tokens = jax.random.randint(RNG, (1, 16), 0, cfg.vocab)
    p1 = jnp.zeros((1, cfg.n_patches, cfg.d_model), cfg.cdtype)
    p2 = jnp.ones((1, cfg.n_patches, cfg.d_model), cfg.cdtype)
    l1, _ = fam.forward(cfg, params, tokens, p1)
    l2, _ = fam.forward(cfg, params, tokens, p2)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))
