"""Tracer semantics, Chrome-trace schema, Prometheus exposition, overhead."""

import json
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from benchmarks.check_trace import (
    TraceError,
    check_bench_stages,
    check_required,
    validate_events,
)
from repro.core import SaPOptions
from repro.core.banded import random_banded
from repro.obs import NULL_SPAN, Tracer, get_tracer, span, use_tracer
from repro.serve import AsyncSolverService, SolverEngine
from repro.serve.metrics import DEFAULT_BOUNDS, Histogram, MetricsRegistry

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_span_nesting_and_attrs():
    tr = Tracer()
    with tr.span("outer", n=4) as sp:
        time.sleep(0.001)
        with tr.span("inner") as child:
            child.annotate(hits=2)
        sp.annotate(done=True)
    (root,) = tr.roots()
    assert root.name == "outer"
    assert root.attrs == {"n": 4, "done": True}
    assert [c.name for c in root.children] == ["inner"]
    assert root.children[0].attrs == {"hits": 2}
    assert root.duration_s >= 0.001
    assert root.duration_s >= root.children[0].duration_s
    assert tr.find("inner") and tr.durations()["outer"] == root.duration_s


def test_disabled_tracer_returns_null_span():
    tr = Tracer(enabled=False)
    sp = tr.span("x", a=1)
    assert sp is NULL_SPAN
    assert not sp  # falsy: guards `if sp: sp.annotate(...)` call sites
    with sp:
        assert sp.sync("v") == "v"
        sp.annotate(b=2)
    assert tr.roots() == []


def test_module_span_without_active_tracer_is_null():
    assert get_tracer() is None
    assert span("anything") is NULL_SPAN


def test_use_tracer_nests_and_restores():
    t1, t2 = Tracer(), Tracer()
    with use_tracer(t1):
        assert get_tracer() is t1
        with use_tracer(t2):
            assert get_tracer() is t2
            with span("on-t2"):
                pass
        assert get_tracer() is t1
    assert get_tracer() is None
    assert [s.name for s in t2.roots()] == ["on-t2"]
    assert t1.roots() == []


def test_thread_safety_per_thread_stacks():
    tr = Tracer()

    def worker(i):
        with tr.span(f"w{i}"):
            with tr.span("child"):
                time.sleep(0.001)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    roots = tr.roots()
    assert len(roots) == 8  # one root per thread, never cross-adopted
    assert {r.name for r in roots} == {f"w{i}" for i in range(8)}
    assert all(len(r.children) == 1 for r in roots)
    # NOTE: don't assert 8 distinct tids -- the OS recycles thread idents
    # when an early worker exits before a later one starts


def test_record_retroactive_span():
    tr = Tracer()
    t0 = tr.now()
    time.sleep(0.001)
    tr.record("request", t0, tr.now(), rid=7)
    (root,) = tr.roots()
    assert root.name == "request" and root.attrs["rid"] == 7
    assert root.duration_s >= 0.001


def test_summary_tree():
    tr = Tracer()
    with tr.span("solve"):
        with tr.span("factor"):
            pass
        with tr.span("krylov"):
            pass
    text = tr.summary()
    assert "solve" in text and "  factor" in text and "  krylov" in text
    assert "% parent" in text


# ---------------------------------------------------------------------------
# Chrome trace export + validator
# ---------------------------------------------------------------------------


def _traced_forest():
    tr = Tracer()
    with tr.span("a", nan=float("nan")):
        with tr.span("b"):
            pass
    # overlapping retroactive spans (the serve.request pattern)
    t = tr.now()
    tr.record("req", t - 0.01, t - 0.002)
    tr.record("req", t - 0.008, t - 0.001)
    return tr


def test_chrome_events_validate(tmp_path):
    tr = _traced_forest()
    events = tr.to_chrome_events()
    pairs = validate_events(events)
    assert pairs == {"a": 1, "b": 1, "req": 2}
    check_required(pairs, ["a", "b"])
    with pytest.raises(TraceError):
        check_required(pairs, ["missing-span"])
    # NaN attrs must still produce strict JSON
    path = tr.export_chrome(str(tmp_path / "trace.json"))
    doc = json.loads(Path(path).read_text())
    assert validate_events(doc["traceEvents"])
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"process_name", "thread_name"} <= names


def test_validator_rejects_unbalanced():
    with pytest.raises(TraceError):
        validate_events(
            [{"name": "x", "ph": "B", "pid": 1, "tid": 1, "ts": 0.0}]
        )
    with pytest.raises(TraceError):
        validate_events(
            [{"name": "x", "ph": "E", "pid": 1, "tid": 1, "ts": 0.0}]
        )
    with pytest.raises(TraceError):
        validate_events([{"name": "x", "ph": "B", "tid": 1, "ts": 0.0}])


def test_check_bench_stages(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(
        {"rows": [{"name": "r", "stages": {"lu_spk": 0.6, "krylov": 0.4}}]}
    ))
    assert check_bench_stages(good) == 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(
        {"rows": [{"name": "r", "stages": {"lu_spk": 0.4, "krylov": 0.4}}]}
    ))
    with pytest.raises(TraceError):
        check_bench_stages(bad)
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"rows": [{"name": "r"}]}))
    with pytest.raises(TraceError):
        check_bench_stages(empty)


def test_traced_solve_example_smoke(tmp_path):
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "traced_solve.py"),
         "--smoke", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    doc = json.loads((tmp_path / "trace.json").read_text())
    pairs = validate_events(doc["traceEvents"])
    check_required(
        pairs, ["reorder", "factor.lu", "factor.spike", "krylov"]
    )


# ---------------------------------------------------------------------------
# lifecycle spans land on the active tracer
# ---------------------------------------------------------------------------


def test_engine_spans_and_stage_split():
    import jax.numpy as jnp

    from repro.core import batch_factor, batch_plan

    opts = SaPOptions(p=4, variant="C", tol=1e-6)
    bands = [np.float32(random_banded(256, 4, d=1.1, seed=s)) for s in (0, 1)]
    bmat = jnp.stack([
        np.random.default_rng(s).normal(size=256).astype(np.float32)
        for s in (0, 1)
    ])
    tr = Tracer()
    with use_tracer(tr):
        bfac = batch_factor(batch_plan(bands, opts))
        bfac.solve_batch(bmat)
    names = {s.name for s in tr.walk()}
    assert {"factor.batch", "krylov"} <= names
    kr = tr.find("krylov")[0]
    conv = kr.attrs["convergence"]
    assert conv["converged"] is True and conv["iterations"] > 0

    from benchmarks.common import stage_fractions

    stages = stage_fractions(tr)
    assert set(stages) == {"lu_spk", "krylov"}
    assert sum(stages.values()) == pytest.approx(1.0, abs=0.02)
    # and a tracer with no mapped spans yields None, not a bogus dict
    assert stage_fractions(Tracer()) is None


def test_service_request_spans():
    svc = AsyncSolverService(
        SaPOptions(p=4, variant="C", tol=1e-6), max_batch=4, start=False
    )
    try:
        band = np.float32(random_banded(256, 4, d=1.1, seed=0))
        rng = np.random.default_rng(0)
        tr = Tracer()
        with use_tracer(tr):
            futs = [
                svc.submit(band, rng.normal(size=256).astype(np.float32))
                for _ in range(3)
            ]
            while svc.drain_once():
                pass
        assert all(f.result(timeout=1).converged for f in futs)
        # one dispatch span wrapping the engine span, plus one retroactive
        # serve.request root per request covering submit -> resolve
        (disp,) = tr.find("serve.dispatch")
        assert disp.attrs["batch"] == 3
        assert [c.name for c in disp.children] == ["engine.solve_prepared"]
        reqs = tr.find("serve.request")
        assert len(reqs) == 3
        for sp in reqs:
            assert sp.duration_s >= disp.duration_s * 0.5
            assert "queue_s" in sp.attrs and "cache_hit" in sp.attrs
        # and the export of overlapping retroactive spans stays valid
        assert validate_events(tr.to_chrome_events())["serve.request"] == 3
    finally:
        svc.close()


def test_disabled_overhead_under_two_percent():
    """Null-span cost per solve_prepared call < 2% of the warm solve time."""
    eng = SolverEngine(
        SaPOptions(p=4, variant="C", tol=1e-6), max_batch=8, cache_size=16
    )
    band = np.float32(random_banded(256, 4, d=1.1, seed=0))
    rng = np.random.default_rng(0)

    def one_pass():
        from repro.core.batched import bucket_shape

        b = rng.normal(size=256).astype(np.float32)
        from repro.serve.solver_engine import SolveRequest

        req = SolveRequest(rid=0, band=band, b=b)
        bkt = bucket_shape(256, 4, 4, "pow2")
        eng.solve_prepared([req], bkt)

    one_pass()  # warm the jit caches
    t0 = time.perf_counter()
    for _ in range(5):
        one_pass()
    warm_solve_s = (time.perf_counter() - t0) / 5

    # per-site cost of an instrumented span with tracing disabled
    with use_tracer(Tracer(enabled=False)):
        n = 10_000
        t0 = time.perf_counter()
        for _ in range(n):
            with span("engine.solve_prepared", bucket="256x4", batch=1):
                pass
        per_site_s = (time.perf_counter() - t0) / n
    # the hot path crosses a handful of span sites per solve; even 10x
    # that stays far under the 2% budget
    assert per_site_s * 10 < 0.02 * warm_solve_s, (
        f"null-span overhead {per_site_s * 1e9:.0f} ns/site vs warm solve "
        f"{warm_solve_s * 1e6:.0f} us"
    )


# ---------------------------------------------------------------------------
# metrics: quantile edges + prometheus exposition
# ---------------------------------------------------------------------------


def test_quantile_edges():
    h = Histogram("t", bounds=(1.0, 2.0, 4.0))
    assert np.isnan(h.quantile(0.0))
    assert np.isnan(h.quantile(0.5))
    assert np.isnan(h.quantile(1.0))
    for v in (0.5, 1.5, 3.0, 9.0):
        h.observe(v)
    assert h.quantile(0.0) == 0.5  # exact observed min, not a bucket edge
    assert h.quantile(1.0) == 9.0  # exact observed max (overflow bucket)
    assert h.quantile(0.5) == 2.0  # upper edge of the rank-2 bucket
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("requests").inc(3)
    reg.counter("shed_total").inc()  # already suffixed: not doubled
    reg.gauge("queue-depth.now").set(5)
    h = reg.histogram("latency_s", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = reg.to_prometheus(prefix="sap_")
    lines = text.splitlines()
    assert "# TYPE sap_requests_total counter" in lines
    assert "sap_requests_total 3" in lines
    assert "sap_shed_total 1" in lines
    assert text.count("shed_total_total") == 0
    assert "sap_queue_depth_now 5" in lines  # sanitized name
    assert 'sap_latency_s_bucket{le="0.1"} 1' in lines
    assert 'sap_latency_s_bucket{le="1"} 2' in lines  # cumulative
    assert 'sap_latency_s_bucket{le="+Inf"} 3' in lines
    assert "sap_latency_s_sum 2.55" in lines
    assert "sap_latency_s_count 3" in lines
    assert text.endswith("\n")


def test_service_render_and_hist_bounds():
    bounds = (0.01, 0.1, 1.0)
    svc = AsyncSolverService(
        SaPOptions(p=4, variant="C", tol=1e-6),
        max_batch=4,
        hist_bounds=bounds,
        start=False,
    )
    try:
        assert svc.metrics.histogram("time_in_queue_s").bounds == bounds
        text = svc.render()
        assert "# TYPE" in text and "time_in_queue_s" in text
    finally:
        svc.close()
    # default bounds when not overridden
    svc2 = AsyncSolverService(
        SaPOptions(p=4, variant="C", tol=1e-6), max_batch=4, start=False
    )
    try:
        assert (
            svc2.metrics.histogram("time_in_queue_s").bounds == DEFAULT_BOUNDS
        )
    finally:
        svc2.close()


def test_solver_config_hist_bounds_roundtrip():
    from repro.configs.sap_solver import SolverConfig

    cfg = SolverConfig(name="t", n=512, k=8, hist_bounds=(0.5, 5.0))
    svc = cfg.to_service(p=4, start=False)
    try:
        assert svc.metrics.histogram("time_in_queue_s").bounds == (0.5, 5.0)
    finally:
        svc.close()


def test_engine_time_split_stats():
    eng = SolverEngine(SaPOptions(p=4, variant="C", tol=1e-6), max_batch=8)
    band = np.float32(random_banded(256, 4, d=1.1, seed=0))
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit_system(band, rng.normal(size=256).astype(np.float32))
    eng.run_until_drained()
    st = eng.stats_snapshot()
    assert st["factor_seconds_total"] > 0.0  # one miss was factored
    assert st["solve_seconds_total"] > 0.0
    assert st["solve_seconds"] == pytest.approx(
        st["factor_seconds_total"] + st["solve_seconds_total"], rel=1e-6
    )
    assert eng.systems_per_second > 0.0
