"""Unit tests: optimizer, data pipeline, serve engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.configs import get_config
from repro.data import BinTokenDataset, DataConfig, SyntheticLM
from repro.models import get_family
from repro.serve import Request, ServeEngine


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = optim.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = optim.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_warmup_and_cosine():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(optim.schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(optim.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(optim.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_clip_norm_applied():
    cfg = optim.AdamWConfig(lr=0.0, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = optim.init(params)
    _, _, m = optim.apply_updates(cfg, params, {"w": jnp.full(4, 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_zero1_pspecs_shards_divisible_dims():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    pspecs = {"a": P(None, "model"), "b": P("model", None)}
    params = {"a": jnp.zeros((8, 6)), "b": jnp.zeros((3, 5))}
    out = optim.zero1_pspecs(pspecs, params, FakeMesh())
    assert out["a"] == P("data", "model")  # dim0=8 divisible by 4
    assert out["b"] == P("model", None)  # 3 and 5 not divisible


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_synthetic_structure_learnable():
    dc = DataConfig(vocab=97, seq_len=32, global_batch=4, noise=0.0)
    b = SyntheticLM(dc).batch(0)["tokens"]
    nxt = (dc.mult * b[:, :-1] + dc.add) % dc.vocab
    np.testing.assert_array_equal(b[:, 1:], nxt)


def test_bin_dataset(tmp_path):
    path = tmp_path / "toks.bin"
    np.arange(10_000, dtype=np.int32).tofile(path)
    dc = DataConfig(vocab=50_000, seq_len=64, global_batch=4)
    ds = BinTokenDataset(path, dc)
    b = ds.batch(3)
    assert b["tokens"].shape == (4, 64)
    b2 = BinTokenDataset(path, dc).batch(3)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------


def test_engine_drains_and_is_deterministic():
    cfg = get_config("stablelm-1.6b", reduced=True)
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=5)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    # same prompt -> same continuation regardless of slot/batching order
    again = Request(rid=99, prompt=[2, 2, 3], max_new_tokens=5)
    eng2 = ServeEngine(cfg, params, slots=2, max_len=64)
    eng2.submit(again)
    eng2.run_until_drained()
    ref = Request(rid=100, prompt=[2, 2, 3], max_new_tokens=5)
    eng3 = ServeEngine(cfg, params, slots=4, max_len=64)
    eng3.submit(ref)
    eng3.run_until_drained()
    assert again.out == ref.out
