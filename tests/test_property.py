"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import SaPOptions, solve_banded
from repro.core.banded import (
    band_to_dense,
    dense_to_band,
    random_banded,
)
from repro.core import reorder as R
from repro.core.sparse import random_sparse
from repro.kernels import ops
from repro.optim import compress

COMMON = dict(deadline=None, max_examples=15, print_blob=True)


@given(
    n=st.integers(8, 60),
    k=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
@settings(**COMMON)
def test_band_roundtrip_property(n, k, seed):
    k = min(k, n - 1)
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=seed))
    band2 = dense_to_band(band_to_dense(band), k)
    np.testing.assert_allclose(np.asarray(band), np.asarray(band2), atol=1e-6)


@given(
    n=st.integers(40, 150),
    k=st.integers(1, 5),
    p=st.integers(1, 6),
    d=st.floats(1.0, 4.0),
    variant=st.sampled_from(["C", "D"]),
    seed=st.integers(0, 10_000),
)
@settings(**COMMON)
def test_sap_solves_diagonally_dominant_systems(n, k, p, d, variant, seed):
    """Invariant: for d >= 1 the SaP solver converges and matches the dense
    solution to f32 accuracy, for any (n, k, p, variant)."""
    k = min(k, max(1, n // (3 * p)))
    band = jnp.asarray(random_banded(n, k, d=d, seed=seed), jnp.float32)
    dense = np.asarray(band_to_dense(band), dtype=np.float64)
    xstar = np.random.default_rng(seed).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)
    sol = solve_banded(band, b, SaPOptions(p=p, variant=variant, tol=1e-6,
                                           maxiter=400))
    assert sol.converged
    err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
    assert err < 5e-3


@given(
    n=st.integers(20, 120),
    seed=st.integers(0, 10_000),
)
@settings(**COMMON)
def test_reorderings_are_permutations(n, seed):
    csr = random_sparse(n, d=1.5, shuffle=True, seed=seed)
    db = R.diagonal_boosting(csr)
    cm = R.cuthill_mckee(R.symmetrize(csr))
    assert sorted(db.tolist()) == list(range(n))
    assert sorted(cm.tolist()) == list(range(n))


@given(
    t=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([4, 8, 16]),
    dd=st.sampled_from([4, 8]),
    seed=st.integers(0, 1000),
)
@settings(**COMMON)
def test_chunked_scan_equals_sequential(t, chunk, dd, seed):
    """The SaP-scan invariant: chunked == sequential for any chunking."""
    rng = np.random.default_rng(seed)
    shape = (1, 1, t, dd)
    r = jnp.asarray(rng.normal(size=shape), jnp.float32)
    k = jnp.asarray(rng.normal(size=shape), jnp.float32)
    v = jnp.asarray(rng.normal(size=shape), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.normal(size=shape), jnp.float32) * 0.5)
    u = jnp.asarray(rng.normal(size=(1, dd)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(1, 1, dd, dd)), jnp.float32) * 0.1
    from repro.kernels import ref

    o_seq, s_seq = ref.wkv6_ref(r, k, v, logw, u, s0)
    o_chk, s_chk = ops.wkv6(r, k, v, logw, u, s0, chunk=chunk, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_seq), np.asarray(o_chk),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_seq), np.asarray(s_chk),
                               rtol=5e-4, atol=5e-4)


@given(
    k=st.sampled_from([2, 4, 8]),
    m=st.integers(2, 6),
    p=st.integers(1, 4),
    dtype=st.sampled_from(["float32", "float64"]),
    seed=st.integers(0, 10_000),
)
@settings(deadline=None, max_examples=10, print_blob=True)
def test_btf_bts_interpret_matches_ref(k, m, p, dtype, seed):
    """Kernel invariant: the Pallas btf/bts kernels in interpret mode agree
    with the pure-jnp references for any (P, M, K) and storage dtype.

    The kernels *compute* in f32 and store in the input dtype (mixed
    precision, paper Sec. 3.1), so agreement is at f32 level even when the
    storage dtype is float64 (and without the x64 flag float64 degrades to
    float32 in both paths anyway).
    """
    rng = np.random.default_rng(seed)
    dt = jnp.dtype(dtype)
    d = jnp.asarray(rng.normal(size=(p, m, k, k)), dt) + 4 * jnp.eye(k, dtype=dt)
    e = jnp.asarray(rng.normal(size=(p, m, k, k)) * 0.3, dt)
    f = jnp.asarray(rng.normal(size=(p, m, k, k)) * 0.3, dt)
    b = jnp.asarray(rng.normal(size=(p, m, k, 2)), dt)

    fr = ops.block_tridiag_factor(d, e, f, impl="jnp")
    fp = ops.block_tridiag_factor(d, e, f, impl="interpret")
    tol = dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fr.sinv, np.float64),
                               np.asarray(fp.sinv, np.float64), **tol)
    np.testing.assert_allclose(np.asarray(fr.l, np.float64),
                               np.asarray(fp.l, np.float64), **tol)

    xr = ops.block_tridiag_solve(fr, b, impl="jnp")
    xp = ops.block_tridiag_solve(fr, b, impl="interpret")
    np.testing.assert_allclose(np.asarray(xr, np.float64),
                               np.asarray(xp, np.float64), **tol)


@given(
    m=st.sampled_from([1, 2, 3, 5, 8, 16]),
    k=st.sampled_from([2, 4, 8]),
    dtype=st.sampled_from(["float32", "float64"]),
    seed=st.integers(0, 10_000),
)
@settings(deadline=None, max_examples=10, print_blob=True)
def test_bcr_solve_matches_bts_chain(m, k, dtype, seed):
    """Chain invariant: log-depth cyclic reduction solves any random
    block-tridiagonal chain to the same answer as the sequential
    btf_chain/bts_chain sweep -- including non-power-of-two lengths.

    Like the btf/bts kernels, the factors compute at f32 accuracy (and
    float64 degrades to float32 without the x64 flag anyway), so the
    agreement tolerance is f32-level for both storage dtypes.
    """
    from repro.core.block_lu import btf_chain, bts_chain
    from repro.core.cyclic_reduction import bcr_factor, bcr_solve

    rng = np.random.default_rng(seed)
    dt = jnp.dtype(dtype)
    d = jnp.asarray(rng.normal(size=(m, k, k)), dt) + 4 * jnp.eye(k, dtype=dt)
    e = jnp.asarray(rng.normal(size=(m, k, k)) * 0.3, dt)
    f = jnp.asarray(rng.normal(size=(m, k, k)) * 0.3, dt)
    b = jnp.asarray(rng.normal(size=(m, k, 2)), dt)
    x_seq = bts_chain(btf_chain(d, e, f), b)
    x_bcr = bcr_solve(bcr_factor(d, e, f), b)
    np.testing.assert_allclose(
        np.asarray(x_bcr, np.float64), np.asarray(x_seq, np.float64),
        rtol=5e-4, atol=5e-4,
    )
    x_int = ops.bcr_solve(ops.bcr_factor(d, e, f, impl="interpret"), b,
                          impl="interpret")
    np.testing.assert_allclose(
        np.asarray(x_int, np.float64), np.asarray(x_seq, np.float64),
        rtol=5e-4, atol=5e-4,
    )


@given(
    frac=st.floats(0.0, 0.3),
    seed=st.integers(0, 1000),
)
@settings(**COMMON)
def test_dropoff_budget_invariant(frac, seed):
    csr = random_sparse(80, d=1.0, shuffle=False, seed=seed)
    total = np.abs(csr.data).sum()
    out, k_new = R.drop_off(csr, frac)
    removed = total - np.abs(out.data).sum()
    assert removed <= frac * total + 1e-9
    assert k_new <= max(R.half_bandwidth(csr), 0)


@given(seed=st.integers(0, 1000))
@settings(**COMMON)
def test_compressor_error_feedback_invariant(seed):
    """q*scale + err' == g + err exactly: no gradient mass is ever lost."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.01, 100), jnp.float32)
    err = jnp.asarray(rng.normal(size=(64,)) * 0.01, jnp.float32)
    q, scale, new_err = compress.compress(g, err)
    recon = compress.decompress(q, scale) + new_err
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g + err),
                               rtol=1e-5, atol=1e-6)
    assert q.dtype == jnp.int8
