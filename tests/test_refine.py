"""Mixed-precision iterative refinement: f32 factor, f64-accurate solves.

The ``solver="refine"`` path (repro.core.krylov._refine_impl) runs the
Richardson iteration ``x += M^-1 (b - A x)`` with the residual computed in
the outer (RHS / ``iter_dtype``) precision while the SaP preconditioner is
factored and applied in ``precond_dtype``.  Contract under test:

  * the controlled residual IS the true residual (``resnorm`` ~
    ``true_resnorm`` by construction);
  * final accuracy is set by the *outer* dtype, not the factorization
    dtype -- an f32 factorization refines an f64 system to ~1e-10 where
    a plain f32 Krylov solve stalls at f32 rounding (~1e-7);
  * the x64 halves run in a subprocess (the x64 flag is process-global).
"""

import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions, factor, plan_banded, refine, refine_many
from repro.core.banded import band_matvec, band_to_dense, random_banded
from repro.core.sap import resolve_solver

SRC = Path(__file__).resolve().parent.parent / "src"


def _system(n=96, k=3, d=1.3, seed=0):
    band = jnp.asarray(random_banded(n, k, d, seed=seed), jnp.float32)
    x = np.random.default_rng(seed + 1).normal(size=n)
    b = band_matvec(band, jnp.asarray(x, jnp.float32))
    return band, b, x


def test_resolve_solver():
    assert resolve_solver("auto", False) == "bicgstab2"
    assert resolve_solver("auto", True) == "cg"
    assert resolve_solver("refine", False) == "refine"
    assert resolve_solver("bicgstab2", True) == "bicgstab2"
    with pytest.raises(ValueError):
        resolve_solver("gmres", False)


def test_refine_standalone_dense():
    """Raw krylov.refine with an exact-inverse preconditioner: one sweep."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(24, 24)) + 8 * np.eye(24), jnp.float32)
    xstar = rng.normal(size=24)
    b = a @ jnp.asarray(xstar, jnp.float32)
    ainv = jnp.linalg.inv(a)
    res = refine(lambda v: a @ v, b, precond=lambda r: ainv @ r, tol=1e-5)
    assert bool(res.converged)
    assert float(res.iterations) <= 3
    assert float(res.true_resnorm) <= 1e-5
    # the refinement residual IS the true residual
    assert float(res.resnorm) == pytest.approx(float(res.true_resnorm),
                                               rel=1e-3, abs=1e-9)


def test_refine_solver_through_lifecycle():
    band, b, xstar = _system()
    opts = SaPOptions(p=4, variant="C", solver="refine", tol=1e-6)
    fac = factor(plan_banded(band, opts))
    assert fac.solver == "refine"
    res = fac.solve(b)
    assert bool(res.converged)
    assert float(res.true_resnorm) <= 1e-6
    assert np.abs(np.asarray(res.x) - xstar).max() < 1e-3


def test_refine_matches_bicgstab2_solution():
    band, b, _ = _system(seed=5)
    xs = {}
    for solver in ("refine", "bicgstab2"):
        opts = SaPOptions(p=4, variant="C", solver=solver, tol=1e-6)
        res = factor(plan_banded(band, opts)).solve(b)
        assert bool(res.converged), solver
        xs[solver] = np.asarray(res.x)
    np.testing.assert_allclose(xs["refine"], xs["bicgstab2"],
                               rtol=1e-4, atol=1e-5)


def test_refine_record_history():
    band, b, _ = _system(seed=7)
    opts = SaPOptions(p=4, variant="C", solver="refine", tol=1e-6,
                      maxiter=50)
    res = factor(plan_banded(band, opts)).solve(b, record_history=True)
    assert res.history is not None and res.history.shape == (50,)
    hist = np.asarray(res.history)
    rec = hist[~np.isnan(hist)]
    assert rec.size == int(np.ceil(float(res.iterations)))
    assert rec[-1] <= 1e-6  # last recorded sweep is the converged one
    if rec.size > 1:  # monotone contraction for a dominant system
        assert rec[-1] < rec[0]


def test_refine_many_columns_independent():
    rng = np.random.default_rng(3)
    band, _, _ = _system(seed=9)
    dense = np.asarray(band_to_dense(band))
    xs = rng.normal(size=(96, 4))
    bmat = jnp.asarray(dense @ xs, jnp.float32)
    opts = SaPOptions(p=4, variant="C", solver="refine", tol=1e-5,
                      maxiter=100)
    fac = factor(plan_banded(band, opts))
    res = fac.solve_many(bmat)
    assert res.converged.shape == (4,) and bool(res.converged.all())
    one = fac.solve(bmat[:, 0])
    assert float(one.iterations) == float(res.iterations[0])
    # the raw multi-RHS helper agrees with the lifecycle path
    a = jnp.asarray(dense, jnp.float32)
    ainv = jnp.linalg.inv(a)
    raw = refine_many(lambda v: a @ v, bmat, precond=lambda r: ainv @ r,
                      tol=1e-5)
    assert bool(raw.converged.all())


# ---------------------------------------------------------------------------
# acceptance (float64, subprocess): f32 factorization + f64 refinement
# reaches 1e-10 where the plain f32 iteration stalls at f32 rounding
# ---------------------------------------------------------------------------

ACCEPTANCE_SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_to_dense, oscillatory_banded

n, k, p = 1024, 8, 8
band = jnp.asarray(oscillatory_banded(n, k, d=0.5, seed=0))
dense = np.asarray(band_to_dense(band))
xstar = np.random.default_rng(0).normal(size=n)
b = jnp.asarray(dense @ xstar)  # float64 RHS

# plain f32 Krylov: factor f32, iterate f32 -- stalls near f32 rounding
opts32 = SaPOptions(p=p, variant="E", tol=1e-12, maxiter=200,
                    precond_dtype="float32", iter_dtype="float32")
r32 = factor(plan_banded(band, opts32)).solve(b)
print("f32 krylov:", bool(r32.converged), float(r32.true_resnorm))
assert float(r32.true_resnorm) > 1e-8, (
    "f32 baseline unexpectedly reached f64-level accuracy")

# mixed precision: SAME f32 factorization, f64 refinement outer loop
optsmp = SaPOptions(p=p, variant="E", solver="refine", tol=1e-11,
                    maxiter=200, precond_dtype="float32",
                    iter_dtype="float64")
rmp = factor(plan_banded(band, optsmp)).solve(b)
print("f32-factor/f64-refine:", bool(rmp.converged),
      float(rmp.true_resnorm), float(rmp.iterations))
assert bool(rmp.converged), "refinement did not converge"
assert float(rmp.true_resnorm) <= 1e-10, float(rmp.true_resnorm)
err = float(np.abs(np.asarray(rmp.x) - xstar).max())
print("max |x - x*| =", err)

# f64 refinement on the fused factorization path agrees
optsf = SaPOptions(p=p, variant="E", solver="refine", tol=1e-11,
                   maxiter=200, precond_dtype="float32",
                   iter_dtype="float64", fused_factor="on")
rf = factor(plan_banded(band, optsf)).solve(b)
assert bool(rf.converged) and float(rf.true_resnorm) <= 1e-10, (
    float(rf.true_resnorm))
print("REFINE_ACCEPTANCE_OK")
"""


def test_refine_acceptance_f32_factor_f64_accuracy():
    proc = subprocess.run(
        [sys.executable, "-c", ACCEPTANCE_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "REFINE_ACCEPTANCE_OK" in proc.stdout
