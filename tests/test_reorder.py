"""Unit tests: DB / CM reorderings, drop-off, third stage (vs scipy refs)."""

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.core import reorder as R
from repro.core.sparse import csr_from_dense, random_sparse


def _log_diag_product(csr, perm=None):
    dense = csr.to_dense()
    if perm is not None:
        dense = dense[perm]
    d = np.abs(np.diag(dense))
    return np.sum(np.log(np.maximum(d, 1e-300)))


def _scramble_rows(csr, seed=0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(csr.n)
    return R.permute_rows(csr, perm)


class TestDB:
    def test_perm_is_valid(self):
        csr = _scramble_rows(random_sparse(200, d=2.0, seed=1))
        perm = R.diagonal_boosting(csr)
        assert sorted(perm.tolist()) == list(range(200))

    def test_boosts_diagonal_product(self):
        csr = _scramble_rows(random_sparse(200, d=2.0, seed=2), seed=3)
        before = _log_diag_product(csr)
        perm = R.diagonal_boosting(csr)
        after = _log_diag_product(csr, perm)
        assert after > before + 10.0

    def test_matches_scipy_assignment_quality(self):
        """Paper Sec 4.2.1: DB quality == MC64 quality (same diag product).
        scipy's min_weight_full_bipartite_matching is our MC64 stand-in."""
        csr = _scramble_rows(random_sparse(150, d=1.5, seed=4), seed=5)
        perm = R.diagonal_boosting(csr)
        ours = _log_diag_product(csr, perm)
        m = sp.csr_matrix(csr.to_dense())
        mw = m.copy()
        mw.data = -np.log(np.abs(mw.data))  # min-sum == max-product
        row, col = csgraph.min_weight_full_bipartite_matching(
            sp.csr_matrix(mw)
        )
        ref_perm = np.empty(csr.n, dtype=np.int64)
        ref_perm[col] = row
        ref = _log_diag_product(csr, ref_perm)
        assert ours >= ref - 1e-6 * abs(ref) - 1e-9

    def test_scaling_factors_produce_i_matrix(self):
        csr = _scramble_rows(random_sparse(80, d=2.0, seed=6), seed=7)
        perm, r_scale, c_scale = R.diagonal_boosting(csr, return_scaling=True)
        dense = csr.to_dense()
        scaled = (r_scale[:, None] * dense * c_scale[None, :])[perm]
        diag = np.abs(np.diag(scaled))
        offmax = np.max(np.abs(scaled), axis=1)
        # I-matrix: |diag| ~ 1, off-diagonal <= ~1
        assert np.all(diag > 1e-8)
        assert np.max(offmax / np.maximum(diag, 1e-30)) < 1e6


class TestCM:
    def test_perm_is_valid(self):
        csr = random_sparse(300, d=1.0, seed=8)
        perm = R.cuthill_mckee(R.symmetrize(csr))
        assert sorted(perm.tolist()) == list(range(300))

    def test_reduces_bandwidth(self):
        csr = random_sparse(400, d=1.0, shuffle=True, seed=9)
        k_before = R.half_bandwidth(csr)
        perm = R.cuthill_mckee(R.symmetrize(csr))
        k_after = R.half_bandwidth(R.permute_symmetric(csr, perm))
        assert k_after < k_before / 4

    def test_competitive_with_scipy_rcm(self):
        """Paper Sec 4.2.2: CM bandwidth within ~2x of Harwell MC60 (median
        relative diff ~0%); scipy's reverse_cuthill_mckee is the stand-in."""
        csr = random_sparse(500, d=1.0, shuffle=True, seed=10)
        perm = R.cuthill_mckee(R.symmetrize(csr))
        k_ours = R.half_bandwidth(R.permute_symmetric(csr, perm))
        m = sp.csr_matrix(csr.to_dense() != 0)
        rcm = csgraph.reverse_cuthill_mckee(m, symmetric_mode=False)
        k_ref = R.half_bandwidth(R.permute_symmetric(csr, np.asarray(rcm)))
        assert k_ours <= 2 * max(k_ref, 1)

    def test_disconnected_graph(self):
        dense = np.zeros((10, 10))
        dense[:5, :5] = np.eye(5) + np.eye(5, k=1) + np.eye(5, k=-1)
        dense[5:, 5:] = np.eye(5) + np.eye(5, k=1) + np.eye(5, k=-1)
        csr = csr_from_dense(dense)
        perm = R.cuthill_mckee(R.symmetrize(csr))
        assert sorted(perm.tolist()) == list(range(10))


class TestDropOff:
    def test_budget_honored(self):
        csr = random_sparse(200, d=1.0, shuffle=False, seed=11)
        total = np.abs(csr.data).sum()
        dropped_csr, k_new = R.drop_off(csr, 0.05)
        removed = total - np.abs(dropped_csr.data).sum()
        assert removed <= 0.05 * total + 1e-9
        assert k_new <= R.half_bandwidth(csr)

    def test_zero_budget_keeps_all(self):
        csr = random_sparse(100, d=1.0, seed=12)
        out, k = R.drop_off(csr, 0.0)
        assert out.nnz == csr.nnz


class TestThirdStage:
    def test_reduces_partition_bandwidth(self):
        # banded matrix whose interior has large K, per-partition CM helps
        csr = random_sparse(256, d=1.0, shuffle=True, seed=13)
        perm = R.cuthill_mckee(R.symmetrize(csr))
        csr_r = R.permute_symmetric(csr, perm)
        k = max(R.half_bandwidth(csr_r), 1)
        band = R.csr_to_band(csr_r, k)
        n_pad = 256
        perm3, k_i = R.third_stage(band, k, 4, n_pad // 4)
        assert sorted(perm3.tolist()) == list(range(n_pad))
        assert np.all(k_i <= k)
