"""Roofline / HLO-analysis unit tests (calibrated against XLA on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_stats import analyze_hlo, _parse_type
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    analyze,
    model_flops,
)


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_type_bytes():
    assert _parse_type("f32[8,128]{1,0}")[0] == 8 * 128 * 4
    assert _parse_type("bf16[2,2]")[0] == 8
    assert _parse_type("(f32[4], s32[2])")[0] == 16 + 8
    assert _parse_type("pred[]")[0] == 1


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    st = analyze_hlo(_hlo(lambda x: x @ x, a))
    assert st.flops == pytest.approx(2 * 256**3, rel=0.01)


def test_scan_trip_count_multiplies():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(x):
        def body(c, _):
            return c @ c * 0.5, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    st = analyze_hlo(_hlo(scanned, a))
    assert st.flops == pytest.approx(12 * 2 * 128**3, rel=0.05)


def test_grad_flops_roughly_double():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def lossf(w, x):
        return jnp.sum((x @ w) ** 2)

    st = analyze_hlo(_hlo(lambda w, x: jax.grad(lossf)(w, x), a, a))
    assert st.flops == pytest.approx(2 * 2 * 128**3, rel=0.1)


def test_remat_adds_recompute_flops():
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def make(remat):
        def lossf(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            b = jax.checkpoint(body) if remat else body
            h, _ = jax.lax.scan(b, x, ws)
            return jnp.sum(h**2)

        return lambda ws, x: jax.grad(lossf)(ws, x)

    plain = analyze_hlo(_hlo(make(False), ws, x)).flops
    remat = analyze_hlo(_hlo(make(True), ws, x)).flops
    assert remat > plain * 1.2  # ~4/3x expected


def test_analyze_bottleneck_selection():
    roof = analyze({}, "", chips=256, model_flops_global=0.0)
    assert roof.bottleneck in ("compute", "memory", "collective")
    # compute-dominated synthetic numbers
    hlo = ""  # empty -> all zero; construct directly instead
    from repro.launch.roofline import Roofline

    assert PEAK_FLOPS > HBM_BW > ICI_BW


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_config
    from repro.models.api import SHAPES

    dense = get_config("phi3-mini-3.8b")
    moe = get_config("mixtral-8x22b")
    shp = SHAPES["train_4k"]
    mf_dense = model_flops(dense, shp)
    mf_moe = model_flops(moe, shp)
    # mixtral-8x22b active ~39B vs total ~141B: active flops must be used
    from repro.launch.roofline import active_params

    assert active_params(moe) < 0.4 * moe.params_count()
    assert mf_moe > mf_dense  # still bigger than phi3 (39B > 3.8B active)


def test_collective_parse_shard_map_psum():
    """A hand-built psum inside shard_map must appear as all-reduce bytes.
    Uses the 1-device trivial mesh: XLA still emits the op metadata-free,
    so run on the real parser via a crafted HLO snippet instead."""
    hlo = """HloModule test, is_scheduled=true

ENTRY %main.1 (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[128,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
}
"""
    st = analyze_hlo(hlo)
    assert st.coll["all-reduce"]["bytes"] == 128 * 128 * 4
    assert st.coll["all-reduce"]["count"] == 1
