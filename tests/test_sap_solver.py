"""End-to-end solver tests: dense banded + sparse pipelines (paper Sec 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions, solve_banded, solve_sparse
from repro.core.banded import band_to_dense, random_banded, random_rhs
from repro.core.sparse import random_sparse


@pytest.mark.parametrize("variant", ["C", "D"])
@pytest.mark.parametrize("n,k,p", [(200, 4, 4), (333, 5, 7), (500, 8, 8)])
def test_dense_banded_f32(n, k, p, variant):
    band = jnp.asarray(random_banded(n, k, d=1.0, seed=42), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(0).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)
    sol = solve_banded(
        band, b, SaPOptions(p=p, variant=variant, tol=1e-6, maxiter=300)
    )
    assert sol.converged
    err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
    assert err < 1e-4


@pytest.mark.parametrize("d,max_c_iters", [(2.0, 1.0), (1.0, 1.5), (0.3, 30.0)])
def test_iterations_grow_as_dominance_drops(d, max_c_iters):
    """Paper Fig 4.2 / Table 4.2: iteration count rises as d falls."""
    band = jnp.asarray(random_banded(400, 6, d=d, seed=1), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(1).normal(size=400)
    sol = solve_banded(
        band,
        jnp.asarray(dense @ xstar, jnp.float32),
        SaPOptions(p=8, variant="C", tol=1e-6, maxiter=500),
    )
    assert sol.converged
    assert sol.iterations <= max_c_iters


def test_coupled_fewer_iterations_than_decoupled():
    """Paper Table 4.1: C_it < D_it (better preconditioner, dearer setup)."""
    band = jnp.asarray(random_banded(480, 8, d=1.0, seed=3), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    b = jnp.asarray(dense @ np.ones(480), jnp.float32)
    it = {}
    for v in ("C", "D"):
        sol = solve_banded(band, b, SaPOptions(p=8, variant=v, tol=1e-6))
        assert sol.converged
        it[v] = sol.iterations
    assert it["C"] <= it["D"]


def test_mixed_precision_preconditioner():
    """Paper Sec 3.1: low-precision preconditioner + full-precision Krylov."""
    band = jnp.asarray(random_banded(512, 8, d=1.0, seed=4), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(2).normal(size=512)
    b = jnp.asarray(dense @ xstar, jnp.float32)
    sol = solve_banded(
        band, b,
        SaPOptions(p=8, variant="C", tol=1e-5, precond_dtype="bfloat16",
                   maxiter=300),
    )
    assert sol.converged
    err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
    assert err < 0.01  # paper's 1% accuracy criterion (bf16 preconditioner)


def test_sparse_pipeline_end_to_end():
    csr = random_sparse(300, avg_nnz_per_row=5.0, d=1.5, shuffle=True, seed=5)
    dense = csr.to_dense()
    xstar = np.asarray(random_rhs(300))
    b = dense @ xstar
    sol = solve_sparse(csr, b, SaPOptions(p=4, variant="C", tol=1e-8))
    assert sol.converged
    # paper's accuracy criterion (Sec 4.3.3): ||x-x*||/||x*|| <= 1%
    err = np.linalg.norm(sol.x - xstar) / np.linalg.norm(xstar)
    assert err < 0.01
    assert sol.info["k_after_reorder"] < 20  # reordering recovered the band


def test_sparse_with_dropoff_still_converges():
    csr = random_sparse(300, avg_nnz_per_row=6.0, d=2.0, shuffle=True, seed=6)
    dense = csr.to_dense()
    xstar = np.random.default_rng(3).normal(size=300)
    sol = solve_sparse(
        csr, dense @ xstar,
        SaPOptions(p=4, variant="C", tol=1e-8, drop_tol=0.02),
    )
    assert sol.converged
    err = np.linalg.norm(sol.x - xstar) / np.linalg.norm(xstar)
    assert err < 0.01


def test_sparse_db_essential_for_zero_diagonal():
    """A matrix with a scrambled (zero) diagonal requires DB to factor."""
    csr = random_sparse(200, d=2.0, shuffle=True, seed=7)
    rng = np.random.default_rng(8)
    row_perm = rng.permutation(200)
    from repro.core.reorder import permute_rows

    scrambled = permute_rows(csr, row_perm)
    dense = scrambled.to_dense()
    xstar = rng.normal(size=200)
    sol = solve_sparse(
        scrambled, dense @ xstar, SaPOptions(p=4, variant="C", tol=1e-8)
    )
    assert sol.converged
    err = np.linalg.norm(sol.x - xstar) / np.linalg.norm(xstar)
    assert err < 0.01
