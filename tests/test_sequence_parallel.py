"""Sequence-parallel SaP-scan: sharded-sequence recurrences must equal the
single-device scan exactly (the cross-shard carry chain is the paper's
reduced system, exact for triangular systems).  Runs on 8 host devices in
a subprocess."""

import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.kernels import ops, ref
from repro.models.sequence_parallel import sp_ssd, sp_wkv6
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((8,), ("data",))
rng = np.random.default_rng(0)
B, H, T, N, Pd, D = 2, 2, 256, 8, 16, 16

# ---- SSD -------------------------------------------------------------
x  = jnp.asarray(rng.normal(size=(B, H, T, Pd)), jnp.float32)
bm = jnp.asarray(rng.normal(size=(B, H, T, N)), jnp.float32)
cm = jnp.asarray(rng.normal(size=(B, H, T, N)), jnp.float32)
la = -jnp.exp(jnp.asarray(rng.normal(size=(B, H, T)), jnp.float32) * 0.5)
s0 = jnp.zeros((B, H, N, Pd), jnp.float32)
y_ref, s_ref = ref.ssd_ref(x, bm, cm, la, s0)
with mesh:
    y_sp, s_stack = jax.jit(sp_ssd(mesh))(x, bm, cm, la)
err_y = float(jnp.abs(y_ref - y_sp).max())
err_s = float(jnp.abs(s_ref - s_stack[-1]).max())
assert err_y < 5e-4 and err_s < 5e-4, (err_y, err_s)
print(f"ssd ok {err_y:.2e} {err_s:.2e}")

# ---- WKV6 ------------------------------------------------------------
r  = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
k  = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
v  = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32)
lw = -jnp.exp(jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.float32) * 0.5)
u  = jnp.asarray(rng.normal(size=(H, D)), jnp.float32)
w0 = jnp.zeros((B, H, D, D), jnp.float32)
o_ref, sw_ref = ref.wkv6_ref(r, k, v, lw, u, w0)
with mesh:
    o_sp, sw_stack = jax.jit(sp_wkv6(mesh))(r, k, v, lw, u)
err_o = float(jnp.abs(o_ref - o_sp).max())
err_w = float(jnp.abs(sw_ref - sw_stack[-1]).max())
assert err_o < 5e-4 and err_w < 5e-4, (err_o, err_w)
print(f"wkv ok {err_o:.2e} {err_w:.2e}")
print("SEQ_PARALLEL_OK")
"""


def test_sequence_parallel_scan_exact():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={
            "PYTHONPATH": str(SRC),
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "JAX_PLATFORMS": "cpu",
            "PATH": "/usr/bin:/bin",
            "HOME": "/root",
        },
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SEQ_PARALLEL_OK" in proc.stdout
