"""AsyncSolverService: futures, scheduling, admission control, metrics."""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions
from repro.core.banded import band_matvec, oscillatory_banded, random_banded
from repro.serve import (
    AsyncSolverService,
    Cancelled,
    MetricsRegistry,
    QueueFull,
    SolveCancelled,
    band_dominance,
)
from repro.serve.metrics import Counter, Histogram


def _mat(n, k, seed, d=1.1):
    return np.float32(random_banded(n, k, d=d, seed=seed))


def _rhs_for(band, seed):
    n = band.shape[0]
    x = np.random.default_rng(seed).normal(size=n)
    b = np.asarray(band_matvec(jnp.asarray(band), jnp.asarray(x, jnp.float32)))
    return x, b


def _opts(**kw):
    kw.setdefault("p", 4)
    kw.setdefault("variant", "C")
    kw.setdefault("tol", 1e-6)
    kw.setdefault("maxiter", 300)
    return SaPOptions(**kw)


def _service(**kw):
    kw.setdefault("max_batch", 8)
    kw.setdefault("start", False)
    return AsyncSolverService(_opts(), **kw)


# -- metrics primitives -----------------------------------------------------


def test_metrics_counter_and_histogram():
    reg = MetricsRegistry()
    c = reg.counter("reqs")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    h = reg.histogram("lat", bounds=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["max"] == 5.0
    assert h.quantile(0.5) == 1.0  # upper edge of the median's bucket
    assert reg.counter("reqs") is c  # get-or-create is idempotent
    with pytest.raises(ValueError):
        reg.gauge("reqs")  # name collision across types
    with pytest.raises(ValueError):
        reg.histogram("lat", bounds=(1.0, 2.0))  # re-register w/ new bounds
    full = reg.snapshot()
    assert full["counters"]["reqs"] == 3
    assert full["histograms"]["lat"]["count"] == 4


def test_metrics_thread_safety():
    c = Counter("c")
    h = Histogram("h", bounds=(0.5,))

    def spin():
        for _ in range(1000):
            c.inc()
            h.observe(0.25)

    threads = [threading.Thread(target=spin) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000
    assert h.count == 8000 and h.sum == pytest.approx(2000.0)


# -- futures + correctness --------------------------------------------------


def test_futures_resolve_with_correct_solutions():
    svc = _service(start=True)
    try:
        futs, truth = [], []
        for i in range(5):
            band = _mat(150 + 37 * i, 3 + i % 2, seed=i)
            x, b = _rhs_for(band, seed=50 + i)
            futs.append(svc.submit(band, b))
            truth.append(x)
        for fut, x in zip(futs, truth):
            out = fut.result(timeout=180)
            assert fut.done() and not fut.cancelled()
            assert out.converged
            assert out.x.shape == x.shape
            assert np.linalg.norm(out.x - x) / np.linalg.norm(x) < 1e-3
    finally:
        svc.close()
    assert svc.metrics.counter("solved").value == 5
    assert svc.snapshot()["derived"]["solves_per_second"] > 0


def test_future_timeout_then_resolution():
    svc = _service(start=False)  # no drain thread: nothing resolves
    band = _mat(100, 3, seed=0)
    fut = svc.submit(band, _rhs_for(band, seed=0)[1])
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)
    assert not fut.done()
    assert svc.drain_once() == 1
    assert fut.result(timeout=0).converged
    svc.close()


# -- deadline / priority scheduling -----------------------------------------


def test_deadline_shedding_deterministic():
    svc = _service(start=False)
    band = _mat(100, 3, seed=0)
    _, b = _rhs_for(band, seed=0)
    doomed = svc.submit(band, b, deadline_s=0.0)
    alive = svc.submit(band, b, deadline_s=60.0)
    time.sleep(0.002)  # let the zero deadline lapse
    resolved = svc.drain_once()  # shed happens before dispatch
    assert resolved == 1
    assert doomed.cancelled()
    assert doomed.outcome() == Cancelled("deadline")
    with pytest.raises(SolveCancelled, match="deadline"):
        doomed.result(timeout=0)
    assert alive.result(timeout=0).converged
    assert svc.metrics.counter("deadline_misses").value == 1
    assert svc.engine.stats_snapshot()["solved"] == 1  # no wasted batch slot
    svc.close()


def test_default_deadline_from_config_applies():
    svc = _service(start=False, default_deadline_s=0.0)
    band = _mat(100, 3, seed=0)
    fut = svc.submit(band, _rhs_for(band, seed=0)[1])  # no explicit deadline
    time.sleep(0.002)
    svc.drain_once()
    assert fut.cancelled() and fut.outcome() == Cancelled("deadline")
    svc.close()


def test_priority_beats_fifo_and_edf_breaks_ties():
    svc = _service(start=False, max_batch=1)
    small = _mat(100, 3, seed=1)  # one bucket
    big = _mat(600, 3, seed=2)  # a different bucket
    _, bs = _rhs_for(small, seed=0)
    _, bb = _rhs_for(big, seed=0)
    low = svc.submit(small, bs, priority=0)
    late = svc.submit(big, bb, priority=5, deadline_s=600.0)
    soon = svc.submit(big, bb, priority=5, deadline_s=60.0)
    svc.drain_once()  # the high-priority bucket dispatches first...
    assert soon.done() and not late.done() and not low.done()  # ...EDF first
    svc.drain_once()
    assert late.done() and not low.done()
    svc.drain_once()
    assert low.done()
    svc.close()


def test_client_cancel_before_scheduling():
    svc = _service(start=False)
    band = _mat(100, 3, seed=0)
    fut = svc.submit(band, _rhs_for(band, seed=0)[1])
    assert fut.cancel()
    svc.drain_once()
    assert fut.cancelled() and fut.outcome() == Cancelled("client")
    assert svc.engine.stats_snapshot()["solved"] == 0
    assert svc.metrics.counter("client_cancels").value == 1
    svc.close()


# -- admission control -------------------------------------------------------


def test_queue_full_raises_without_blocking():
    svc = _service(start=False, queue_cap=2)
    band = _mat(100, 3, seed=0)
    _, b = _rhs_for(band, seed=0)
    svc.submit(band, b, block=False)
    svc.submit(band, b, block=False)
    with pytest.raises(QueueFull):
        svc.submit(band, b, block=False)
    assert svc.metrics.counter("queue_rejections").value == 1
    with pytest.raises(QueueFull):  # blocking with a timeout also bounds
        svc.submit(band, b, timeout=0.02)
    svc.close(drain=False)


def test_backpressure_unblocks_when_drained():
    svc = _service(start=True, queue_cap=2, max_batch=8)
    band = _mat(100, 3, seed=0)
    _, b = _rhs_for(band, seed=0)
    futs = [svc.submit(band, b, timeout=180) for _ in range(6)]
    for fut in futs:  # every blocked submit eventually got a slot
        assert fut.result(timeout=180).converged
    svc.close()


def test_close_without_drain_sheds_pending():
    svc = _service(start=False)
    band = _mat(100, 3, seed=0)
    fut = svc.submit(band, _rhs_for(band, seed=0)[1])
    svc.close(drain=False)
    assert fut.cancelled() and fut.outcome() == Cancelled("shutdown")
    with pytest.raises(RuntimeError):
        svc.submit(band, _rhs_for(band, seed=0)[1])


# -- dominance-class routing -------------------------------------------------


def test_band_dominance_host_estimator_matches_policy():
    dom = _mat(128, 3, seed=0, d=1.5)
    osc = np.float32(oscillatory_banded(128, 3, d=0.5, seed=0))
    assert band_dominance(dom) >= 1.0
    assert band_dominance(osc) < 1.0
    eye = np.zeros((8, 7), np.float32)
    eye[:, 3] = 1.0
    assert band_dominance(eye) == np.inf


def test_requests_route_to_per_class_variants():
    svc = AsyncSolverService(
        _opts(variant="auto", maxiter=400), max_batch=8, start=False
    )
    # k=3 rounds up to the bucket K=4: the interleaved identity-row
    # embedding keeps E exact on the ill-conditioned matrix, so no
    # K-pinning workaround is needed anymore
    dom = _mat(128, 3, seed=0, d=1.5)
    osc = np.float32(oscillatory_banded(128, 3, d=0.5, seed=1))
    _, bd = _rhs_for(dom, seed=0)
    _, bo = _rhs_for(osc, seed=1)
    fd = svc.submit(dom, bd)
    fo = svc.submit(osc, bo)
    svc.drain_once()
    svc.drain_once()
    rd, ro = fd.result(timeout=0), fo.result(timeout=0)
    assert rd.variant == "C" and rd.converged  # d >= 1: truncated SPIKE
    assert ro.variant == "E" and ro.converged  # d < 1: exact reduced system
    assert np.isfinite(rd.true_resnorm) and np.isfinite(ro.true_resnorm)
    assert not ro.misconverged  # the PR 6 silent-failure mode stays dead
    # the oscillatory matrix is ill-conditioned: check the residual, not
    # the distance to the generating x (which f32 noise amplifies)
    res = np.asarray(
        band_matvec(jnp.asarray(osc), jnp.asarray(ro.x, jnp.float32))
    ) - bo
    assert np.linalg.norm(res) / np.linalg.norm(bo) < 1e-3
    svc.close()


def test_class_override_must_keep_p():
    with pytest.raises(ValueError, match="changes p"):
        AsyncSolverService(
            _opts(p=4),
            class_overrides={"dom": _opts(p=8)},
            start=False,
        )


# -- LRU thrash guard --------------------------------------------------------


def test_thrash_guard_widens_rounding():
    svc = _service(
        start=False,
        rounding="exact",
        cache_size=1,
        thrash_window=4,
        thrash_ratio=0.25,
    )
    # distinct matrices over distinct exact shapes: every solve misses and
    # evicts the previous entry -> eviction rate ~1 per solve
    for i in range(6):
        band = _mat(96 + 4 * i, 3, seed=i)
        svc.submit(band, _rhs_for(band, seed=i)[1])
    while svc.pending:
        svc.drain_once()
    assert svc.rounding == "pow2"
    assert svc.metrics.counter("rounding_widenings").value == 1
    # new arrivals now share pow2 buckets
    band = _mat(97, 3, seed=99)
    fut = svc.submit(band, _rhs_for(band, seed=99)[1])
    svc.drain_once()
    assert fut.result(timeout=0).bucket[0] == 256
    svc.close()


# -- the concurrent soak -----------------------------------------------------


def test_soak_concurrent_mixed_priorities_and_deadlines():
    """N client threads, mixed priorities/deadlines: every future must
    resolve -- solved, shed, or cancelled -- and never hang."""
    svc = AsyncSolverService(
        _opts(variant="auto"), max_batch=8, queue_cap=64, start=True
    )
    n_threads, per_thread = 4, 6
    futs_by_thread = [[] for _ in range(n_threads)]
    errors = []

    def client(tid):
        try:
            rng = np.random.default_rng(tid)
            for j in range(per_thread):
                i = tid * per_thread + j
                band = _mat(100 + 25 * (i % 4), 3, seed=i % 5)
                b = rng.normal(size=band.shape[0]).astype(np.float32)
                # a few impossible deadlines force the shed path under load
                deadline = 0.0 if (i % 7 == 3) else 120.0
                fut = svc.submit(
                    band, b, priority=i % 3, deadline_s=deadline,
                    timeout=120,
                )
                futs_by_thread[tid].append(fut)
        except Exception as e:  # pragma: no cover - surfaced below
            errors.append(e)

    threads = [
        threading.Thread(target=client, args=(tid,))
        for tid in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
        assert not t.is_alive(), "client thread hung on submit"
    assert not errors
    solved = shed = 0
    for futs in futs_by_thread:
        assert len(futs) == per_thread
        for fut in futs:
            out = fut.outcome(timeout=180)  # never hangs
            if isinstance(out, Cancelled):
                assert out.reason in ("deadline", "shutdown")
                shed += 1
            else:
                assert out.converged
                solved += 1
    assert solved + shed == n_threads * per_thread
    assert solved > 0
    svc.close()
    snap = svc.snapshot()
    assert snap["counters"]["solved"] == solved
    assert snap["counters"]["deadline_misses"] == shed
    assert snap["histograms"]["time_in_queue_s"]["count"] == solved
    assert snap["histograms"]["queue_depth"]["count"] == solved + shed
    assert snap["engine"]["solved"] == solved
