"""SolverEngine: bucketed batched serving with an LRU factorization cache."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SaPOptions, batched
from repro.core.banded import band_matvec, random_banded
from repro.serve import SolveRequest, SolverEngine, matrix_fingerprint


def _mat(n, k, seed, d=1.1):
    return np.float32(random_banded(n, k, d=d, seed=seed))


def _rhs_for(band, seed):
    n = band.shape[0]
    x = np.random.default_rng(seed).normal(size=n)
    b = np.asarray(band_matvec(jnp.asarray(band), jnp.asarray(x, jnp.float32)))
    return x, b


def _engine(**kw):
    kw.setdefault("max_batch", 8)
    return SolverEngine(SaPOptions(p=4, variant="C", tol=1e-6, maxiter=300), **kw)


def test_fingerprint_is_content_keyed():
    a = _mat(64, 3, seed=0)
    assert matrix_fingerprint(a) == matrix_fingerprint(a.copy())
    b = a.copy()
    b[10, 1] += 1e-3
    assert matrix_fingerprint(a) != matrix_fingerprint(b)
    # dtype and shape are part of the key
    assert matrix_fingerprint(a) != matrix_fingerprint(a.astype(np.float64))


def test_engine_solves_heterogeneous_fleet():
    eng = _engine()
    mats = [_mat(150 + 37 * i, 3 + i % 2, seed=i) for i in range(5)]
    truth = {}
    for i, band in enumerate(mats):
        x, b = _rhs_for(band, seed=50 + i)
        truth[eng.submit_system(band, b)] = x
    done = eng.run_until_drained()
    assert len(done) == 5 and eng.queue == type(eng.queue)()
    for r in done:
        assert r.result.converged
        x = truth[r.rid]
        assert r.result.x.shape == x.shape  # un-padded to original N
        err = np.linalg.norm(r.result.x - x) / np.linalg.norm(x)
        assert err < 1e-3


def test_engine_factor_runs_once_for_repeated_fingerprints(monkeypatch):
    """The cache-hit call-count contract: re-submitting the same matrix
    across steps (implicit time stepping) factors it exactly once."""
    calls = {"batches": 0, "systems": 0}
    real = batched.batch_factor

    def counting(bpl):
        calls["batches"] += 1
        calls["systems"] += bpl.s
        return real(bpl)

    monkeypatch.setattr(batched, "batch_factor", counting)
    eng = _engine()
    band = _mat(200, 4, seed=7)
    for step in range(4):  # 4 "time steps", fresh RHS each, same matrix
        x, b = _rhs_for(band, seed=step)
        eng.submit_system(band, b)
        done = eng.step()
        assert len(done) == 1 and done[0].result.converged
        assert done[0].result.cache_hit == (step > 0)
    assert calls == {"batches": 1, "systems": 1}
    assert eng.stats["cache_hits"] == 3
    assert eng.stats["cache_misses"] == 1
    assert eng.stats["factored_systems"] == 1
    assert eng.cache_hit_rate == 0.75


def test_engine_duplicate_fingerprints_in_one_batch(monkeypatch):
    """Duplicates inside a single step factor once; later copies are hits."""
    calls = {"systems": 0}
    real = batched.batch_factor

    def counting(bpl):
        calls["systems"] += bpl.s
        return real(bpl)

    monkeypatch.setattr(batched, "batch_factor", counting)
    eng = _engine()
    band = _mat(200, 4, seed=1)
    for i in range(4):  # same Jacobian, 4 outstanding RHS requests
        eng.submit_system(band, _rhs_for(band, seed=i)[1])
    done = eng.step()
    assert len(done) == 4
    assert calls["systems"] == 1
    assert eng.stats["cache_hits"] == 3 and eng.stats["cache_misses"] == 1


def test_engine_lru_eviction_stays_correct():
    eng = _engine(cache_size=1)
    m1, m2 = _mat(200, 4, seed=1), _mat(200, 4, seed=2)
    for rep in range(2):  # alternate matrices: each round evicts the other
        for seed, band in ((rep, m1), (10 + rep, m2)):
            x, b = _rhs_for(band, seed=seed)
            eng.submit_system(band, b)
            (done,) = eng.step()
            assert done.result.converged
            err = np.linalg.norm(done.result.x - x) / np.linalg.norm(x)
            assert err < 1e-3
    assert eng.stats["evictions"] >= 2
    assert eng.cached_factorizations == 1


def test_engine_batch_larger_than_cache_survives_midstep_eviction():
    """Regression: cache_size below the distinct matrices of one step
    must not lose the factorizations the in-flight batch still needs."""
    eng = _engine(max_batch=8, cache_size=1)
    truth = {}
    for i in range(3):  # 3 distinct same-bucket matrices in ONE step
        band = _mat(200, 4, seed=20 + i)
        x, b = _rhs_for(band, seed=i)
        truth[eng.submit_system(band, b)] = x
    done = eng.step()
    assert len(done) == 3
    for r in done:
        assert r.result.converged
        err = np.linalg.norm(r.result.x - truth[r.rid])
        assert err / np.linalg.norm(truth[r.rid]) < 1e-3
    assert eng.cached_factorizations == 1  # LRU still capped
    assert eng.stats["evictions"] == 2


def test_engine_batches_one_bucket_per_step():
    """max_batch caps a step; different buckets never share a batch."""
    eng = _engine(max_batch=2)
    small = [_mat(100, 3, seed=i) for i in range(3)]  # bucket (256, 4, 4)
    big = _mat(600, 3, seed=9)  # bucket (1024, 4, 4)
    for band in [*small, big]:
        eng.submit_system(band, _rhs_for(band, seed=0)[1])
    done1 = eng.step()  # largest bucket first, capped at 2
    assert len(done1) == 2
    assert {r.result.bucket for r in done1} == {(256, 4, 4)}
    done_rest = eng.run_until_drained()
    assert len(done_rest) == 2
    assert eng.stats["solved"] == 4 and eng.stats["steps"] == 3


def test_engine_sticky_auto_variant():
    """variant='auto' pins itself after the first factored batch so cached
    and fresh factorizations always stack into one pytree structure."""
    eng = SolverEngine(
        SaPOptions(p=4, variant="auto", tol=1e-5, maxiter=200), max_batch=4
    )
    band = _mat(200, 4, seed=3, d=1.5)  # dominant -> resolves to C
    x, b = _rhs_for(band, seed=0)
    eng.submit_system(band, b)
    (done,) = eng.step()
    assert done.result.converged
    assert eng.opts.variant == "C"
    # a second, different matrix reuses the pinned variant
    band2 = _mat(230, 4, seed=4, d=1.5)
    x2, b2 = _rhs_for(band2, seed=1)
    eng.submit_system(band2, b2)
    (done2,) = eng.step()
    assert done2.result.converged


def test_engine_step_on_empty_queue_is_noop():
    eng = _engine()
    assert eng.step() == []
    assert eng.stats["steps"] == 0


def test_run_until_drained_warns_on_leftover_work():
    """Regression: hitting max_steps with work still queued used to
    return silently -- now it warns (or raises) with the queue depth."""
    eng = _engine(max_batch=1)
    band = _mat(100, 3, seed=0)
    for i in range(3):
        eng.submit_system(band, _rhs_for(band, seed=i)[1])
    with pytest.warns(RuntimeWarning, match=r"2 request\(s\) still queued"):
        done = eng.run_until_drained(max_steps=1)
    assert len(done) == 1 and eng.pending == 2
    with pytest.raises(RuntimeError, match=r"1 request\(s\) still queued"):
        eng.run_until_drained(max_steps=1, on_leftover="raise")
    assert eng.run_until_drained() and eng.pending == 0  # no leftover: quiet


def test_solve_prepared_accepts_preformed_bucket():
    """An external scheduler can hand the engine a batch + bucket + per-
    call options without touching the internal queue."""
    from repro.serve.solver_engine import SolveRequest as SR

    eng = _engine()
    band = _mat(150, 3, seed=0)
    x, b = _rhs_for(band, seed=0)
    reqs = [SR(rid=0, band=band, b=b)]
    bucket = batched.bucket_shape(150, 3, 4, "pow2")
    opts = SaPOptions(p=4, variant="C", tol=1e-6, maxiter=300)
    done = eng.solve_prepared(reqs, bucket, opts=opts)
    assert len(done) == 1 and done[0].result.converged
    assert done[0].result.variant == "C"
    assert done[0].result.bucket == bucket
    err = np.linalg.norm(done[0].result.x - x) / np.linalg.norm(x)
    assert err < 1e-3
    assert eng.pending == 0 and eng.stats["solved"] == 1
    assert eng.solve_prepared([], bucket) == []


def test_cache_keys_include_options_signature():
    """The same matrix under different variants must occupy distinct
    cache entries (different pytree structures cannot stack)."""
    from repro.serve.solver_engine import SolveRequest as SR

    eng = _engine(cache_size=8)
    band = _mat(150, 3, seed=0)
    _, b = _rhs_for(band, seed=0)
    bucket = batched.bucket_shape(150, 3, 4, "pow2")
    for variant in ("C", "E"):
        opts = SaPOptions(p=4, variant=variant, tol=1e-6, maxiter=300)
        (done,) = eng.solve_prepared([SR(rid=0, band=band, b=b)], bucket,
                                     opts=opts)
        assert done.result.converged and done.result.variant == variant
    assert eng.cached_factorizations == 2
    assert eng.stats["cache_misses"] == 2  # no cross-variant false hit


def test_engine_concurrent_submit_and_step_thread_safe():
    """Client threads submitting while another thread steps: no request
    is lost, every result converges, counters stay consistent."""
    import threading

    eng = _engine(max_batch=4)
    mats = [_mat(100 + 10 * (i % 3), 3, seed=i % 4) for i in range(12)]
    # pre-warm the jit caches so the stepping loop below is fast
    x0, b0 = _rhs_for(mats[0], seed=0)
    eng.submit_system(mats[0], b0)
    eng.run_until_drained()

    def client(tid):
        rng = np.random.default_rng(tid)
        for i in range(4):
            band = mats[(tid * 4 + i) % len(mats)]
            eng.submit_system(band, rng.normal(size=band.shape[0]))

    threads = [threading.Thread(target=client, args=(t,)) for t in range(3)]
    for t in threads:
        t.start()
    done = []
    deadline = time.monotonic() + 120
    while len(done) < 12 and time.monotonic() < deadline:
        done.extend(eng.step())
    for t in threads:
        t.join(timeout=60)
    assert len(done) == 12
    assert all(r.result.converged for r in done)
    assert eng.stats["solved"] == 13 and eng.pending == 0


def test_submit_precomputed_fingerprint_respected():
    eng = _engine()
    band = _mat(100, 3, seed=0)
    _, b = _rhs_for(band, seed=0)
    req = SolveRequest(rid=99, band=band, b=b, fingerprint="custom-fp")
    eng.submit(req)
    assert req.fingerprint == "custom-fp"
    (done,) = eng.step()
    assert done.rid == 99 and done.result.converged
