"""Unit tests: spike blocks, truncated reduced system, SaP preconditioner."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.banded import (
    band_to_block_tridiag,
    block_tridiag_to_dense,
    oscillatory_banded,
    random_banded,
)
from repro.core.spike import build_preconditioner


def _setup(n=80, k=4, p=4, d=1.2, seed=0):
    band = jnp.asarray(random_banded(n, k, d=d, seed=seed))
    bt = band_to_block_tridiag(band, k, p)
    dense = np.asarray(block_tridiag_to_dense(bt))
    return band, bt, dense


def test_spike_blocks_match_direct_inverse():
    band, bt, dense = _setup()
    pc = build_preconditioner(bt, "C", precond_dtype=jnp.float32)
    ni = bt.m * bt.k
    k = bt.k
    for i in range(bt.p - 1):
        ai = dense[i * ni : (i + 1) * ni, i * ni : (i + 1) * ni]
        b_i = np.asarray(bt.b_cpl[i])
        # V_i = A_i^{-1} [0; ...; B_i]; bottom K x K block
        rhs = np.zeros((ni, k))
        rhs[-k:] = b_i
        v_full = np.linalg.solve(ai, rhs)
        np.testing.assert_allclose(
            np.asarray(pc.v_bot[i]), v_full[-k:], rtol=1e-3, atol=1e-4
        )
        # W_{i+1} = A_{i+1}^{-1} [C_{i+1}; 0; ...]; top K x K block
        aip = dense[(i + 1) * ni : (i + 2) * ni, (i + 1) * ni : (i + 2) * ni]
        c_i = np.asarray(bt.c_cpl[i])
        rhs = np.zeros((ni, k))
        rhs[:k] = c_i
        w_full = np.linalg.solve(aip, rhs)
        np.testing.assert_allclose(
            np.asarray(pc.w_top[i]), w_full[:k], rtol=1e-3, atol=1e-4
        )


@pytest.mark.parametrize("d,variant,tol", [(2.0, "C", 5e-3), (1.2, "C", 5e-2)])
def test_coupled_apply_is_near_exact_solve(d, variant, tol):
    """For diagonally dominant A the truncated-SPIKE preconditioner should
    be close to A^{-1} (paper Sec 2.1: spike decay justifies truncation)."""
    band, bt, dense = _setup(d=d)
    pc = build_preconditioner(bt, variant, precond_dtype=jnp.float64)
    rng = np.random.default_rng(1)
    r = rng.normal(size=bt.n_pad)
    z = np.asarray(pc.apply(jnp.asarray(r)))
    res = np.linalg.norm(dense @ z - r) / np.linalg.norm(r)
    assert res < tol


def test_decoupled_apply_solves_block_diagonal():
    band, bt, dense = _setup(d=1.0)
    pc = build_preconditioner(bt, "D", precond_dtype=jnp.float32)
    rng = np.random.default_rng(2)
    r = rng.normal(size=bt.n_pad)
    z = np.asarray(pc.apply(jnp.asarray(r)))
    # zero out coupling blocks -> block diagonal D
    ni = bt.m * bt.k
    dblk = dense.copy()
    for i in range(bt.p - 1):
        dblk[(i + 1) * ni - bt.k : (i + 1) * ni, (i + 1) * ni : (i + 1) * ni + bt.k] = 0
        dblk[(i + 1) * ni : (i + 1) * ni + bt.k, (i + 1) * ni - bt.k : (i + 1) * ni] = 0
    np.testing.assert_allclose(dblk @ z, r, rtol=1e-3, atol=1e-3)


def test_single_partition_coupled_degrades_to_decoupled():
    band = jnp.asarray(random_banded(32, 3, d=1.0, seed=5))
    bt = band_to_block_tridiag(band, 3, 1)
    pc = build_preconditioner(bt, "C")
    assert pc.variant == "D"


def test_coupled_beats_decoupled_consistency():
    """Coupled preconditioner residual should be no worse than decoupled."""
    band, bt, dense = _setup(d=1.0, seed=9)
    rng = np.random.default_rng(3)
    r = rng.normal(size=bt.n_pad)
    res = {}
    for v in ("C", "D"):
        pc = build_preconditioner(bt, v, precond_dtype=jnp.float32)
        z = np.asarray(pc.apply(jnp.asarray(r)))
        res[v] = np.linalg.norm(dense @ z - r)
    assert res["C"] < res["D"]


@pytest.mark.parametrize("n,k,p,d", [(80, 4, 4, 0.5), (64, 4, 2, 0.5),
                                     (96, 3, 5, 1.2)])
def test_exact_variant_apply_is_exact_solve(n, k, p, d):
    """SaP-E solves the banded preconditioner matrix *exactly* (to f32
    roundoff), dominant or not -- unlike C, whose truncation needs d >= 1.
    P=2 exercises the single-interface reduced chain (no e/f blocks)."""
    band = jnp.asarray(random_banded(n, k, d=d, seed=0))
    bt = band_to_block_tridiag(band, k, p)
    dense = np.asarray(block_tridiag_to_dense(bt))
    pc = build_preconditioner(bt, "E", precond_dtype=jnp.float32)
    assert pc.variant == "E"
    assert pc.red_lu is not None and pc.rbar_inv is None
    # reduced chain: one pseudo-partition of (P-1) blocks of size 2K
    assert pc.red_lu.sinv.shape == (1, p - 1, 2 * k, 2 * k)
    r = np.random.default_rng(1).normal(size=bt.n_pad)
    z = np.asarray(pc.apply(jnp.asarray(r, jnp.float32)))
    res = np.linalg.norm(dense @ z - r) / np.linalg.norm(r)
    assert res < 1e-5


def test_exact_variant_robust_where_truncation_fails():
    """Non-decaying spikes at d = 0.5: the truncated apply is O(1) wrong,
    the exact reduced system stays at machine precision."""
    band = jnp.asarray(oscillatory_banded(96, 4, d=0.5, seed=0))
    bt = band_to_block_tridiag(band, 4, 4)
    dense = np.asarray(block_tridiag_to_dense(bt))
    r = np.random.default_rng(2).normal(size=bt.n_pad)
    res = {}
    for v in ("C", "E"):
        pc = build_preconditioner(bt, v, precond_dtype=jnp.float32)
        z = np.asarray(pc.apply(jnp.asarray(r, jnp.float32)))
        res[v] = np.linalg.norm(dense @ z - r) / np.linalg.norm(r)
    assert res["E"] < 1e-4  # f32 direct solve, cond-limited
    assert res["C"] > 0.1  # truncation error is O(1) here
    assert res["C"] > 100 * res["E"]


def test_exact_single_partition_degrades_to_decoupled():
    band = jnp.asarray(random_banded(32, 3, d=1.0, seed=5))
    bt = band_to_block_tridiag(band, 3, 1)
    pc = build_preconditioner(bt, "E")
    assert pc.variant == "D"
    assert pc.red_lu is None


def test_full_spike_mode_matches_ul_mode():
    """Paper Sec 2.2.1: with third-stage reordering the UL shortcut is
    unavailable and whole spikes must be computed; both paths must agree
    on the truncated blocks for a plain banded system."""
    band, bt, dense = _setup(d=1.0, seed=11)
    pc_ul = build_preconditioner(bt, "C", spike_mode="ul")
    pc_full = build_preconditioner(bt, "C", spike_mode="full")
    np.testing.assert_allclose(
        np.asarray(pc_ul.v_bot), np.asarray(pc_full.v_bot), rtol=1e-4,
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(pc_ul.w_top), np.asarray(pc_full.w_top), rtol=1e-4,
        atol=1e-5,
    )
    r = np.random.default_rng(4).normal(size=bt.n_pad)
    z1 = np.asarray(pc_ul.apply(jnp.asarray(r, jnp.float32)))
    z2 = np.asarray(pc_full.apply(jnp.asarray(r, jnp.float32)))
    np.testing.assert_allclose(z1, z2, rtol=1e-4, atol=1e-4)
