"""Integration tests: training loop, checkpointing, fault tolerance."""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, TrainLoop, run_with_restarts


@pytest.fixture()
def ckpt_dir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _mk(ckpt_dir, steps=40, **kw):
    cfg = get_config("stablelm-1.6b", reduced=True)
    tc = TrainConfig(steps=steps, checkpoint_every=20, checkpoint_dir=ckpt_dir,
                     log_every=10, **kw)
    oc = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, noise=0.05)
    return cfg, tc, oc, dc


def test_loss_decreases(ckpt_dir):
    cfg, tc, oc, dc = _mk(ckpt_dir)
    out = TrainLoop(cfg, oc, tc, dc).run()
    losses = [r["loss"] for r in out["log"]]
    assert losses[-1] < losses[0] - 0.5


def test_crash_recovery_resumes_from_checkpoint(ckpt_dir):
    cfg, tc, oc, dc = _mk(ckpt_dir, steps=50)
    calls = {"n": 0}

    def fault(step):
        if step == 30 and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("simulated preemption")

    out, restarts = run_with_restarts(
        lambda: TrainLoop(cfg, oc, tc, dc, fault_hook=fault)
    )
    assert restarts == 1
    assert out["last_step"] == 50


def test_deterministic_data_across_restart(ckpt_dir):
    _, _, _, dc = _mk(ckpt_dir)
    from repro.data import SyntheticLM

    a = SyntheticLM(dc).batch(7)
    b = SyntheticLM(dc).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards partition the batch deterministically
    s0 = SyntheticLM(dc, shard_id=0, n_shards=2).batch(7)
    s1 = SyntheticLM(dc, shard_id=1, n_shards=2).batch(7)
    assert s0["tokens"].shape[0] == dc.global_batch // 2
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_microbatching_matches_full_batch(ckpt_dir):
    """Gradient accumulation must give the same update as the full batch."""
    from repro.train import make_train_step
    from repro import optim

    cfg = get_config("stablelm-1.6b", reduced=True)
    oc = AdamWConfig(lr=1e-3, warmup_steps=0)
    tc1 = TrainConfig(microbatches=1, checkpoint_dir=ckpt_dir)
    tc2 = TrainConfig(microbatches=2, checkpoint_dir=ckpt_dir)
    from repro.models import get_family

    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.PRNGKey(0))
    opt = optim.init(params)
    err = {}
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab)}
    s1 = jax.jit(make_train_step(cfg, oc, tc1))
    s2 = jax.jit(make_train_step(cfg, oc, tc2))
    p1, _, _, m1 = s1(params, opt, err, batch)
    p2, _, _, m2 = s2(params, opt, err, batch)
    # losses match to fp tolerance; params close (clip uses same norm scale)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_grad_compression_still_learns(ckpt_dir):
    cfg, tc, oc, dc = _mk(ckpt_dir, steps=30, grad_compress=True)
    out = TrainLoop(cfg, oc, tc, dc).run()
    losses = [r["loss"] for r in out["log"]]
    assert losses[-1] < losses[0] - 0.3


def test_checkpoint_restore_bitwise(ckpt_dir):
    from repro.train.checkpoint import CheckpointManager

    cm = CheckpointManager(ckpt_dir, keep=2, async_save=False)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    cm.save(5, tree)
    cm.save(10, tree)
    cm.save(15, tree)  # keep=2 -> step 5 garbage-collected
    assert cm.latest_step() == 15
    restored = cm.restore(15, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    import pathlib

    ckpts = list(pathlib.Path(ckpt_dir).glob("step_*.npz"))
    assert len(ckpts) == 2
