"""Convergence regression matrix over SaP variants D / C / E / auto.

Covers both dominance regimes of paper Sec. 2.1.1:
  * d >= 1 (diagonally dominant): truncation is justified, C is the
    paper's workhorse, E matches it at slightly higher setup cost.
  * d < 1 with non-decaying spikes (``oscillatory_banded``): truncation
    breaks down -- only the exact reduced system (E) and the "auto"
    policy that selects it stay robust.

Iteration budgets are fixed so regressions in the preconditioner quality
show up as test failures, not silent slowdowns.  The float64 acceptance
scenario (E converges to 1e-8 where C cannot, d ~ 0.5) runs in a
subprocess because the x64 flag is process-global (see
``test_f64_reference.py``).
"""

import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SaPOptions,
    factor,
    plan,
    plan_banded,
    resolve_variant,
    solve_banded,
)
from repro.core.banded import (
    band_to_dense,
    diag_dominance_factor,
    oscillatory_banded,
    random_banded,
)
from repro.core.sparse import random_sparse

SRC = Path(__file__).resolve().parent.parent / "src"


def _banded_system(gen, n, k, d, seed=0):
    band = jnp.asarray(gen(n, k, d=d, seed=seed), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xstar = np.random.default_rng(seed + 1).normal(size=n)
    b = jnp.asarray(dense @ xstar, jnp.float32)
    return band, xstar, b


# ---------------------------------------------------------------------------
# the regression matrix: (regime, variant) -> iteration budget
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "variant,budget",
    [("D", 20.0), ("C", 5.0), ("E", 2.0), ("auto", 5.0)],
)
def test_banded_dominant_within_budget(variant, budget):
    """d = 1.2: every variant converges; truncation is near-exact."""
    band, xstar, b = _banded_system(random_banded, 400, 6, 1.2, seed=2)
    sol = solve_banded(band, b, SaPOptions(p=8, variant=variant, tol=1e-5,
                                           maxiter=200))
    assert sol.converged
    assert sol.iterations <= budget
    err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
    assert err < 1e-3
    assert sol.info["d_factor"] == pytest.approx(1.2, rel=1e-3)
    if variant == "auto":
        assert sol.info["variant"] == "C"  # d >= 1 -> truncated coupled


@pytest.mark.parametrize(
    "variant,budget",
    [("D", 60.0), ("E", 2.0), ("auto", 2.0)],
)
def test_banded_nondominant_within_budget(variant, budget):
    """d = 0.5 with coherent off-diagonal signs: spikes do not decay.

    The exact reduced system solves the preconditioner band exactly and
    converges immediately; "auto" must pick it.  (Variant C is covered by
    :func:`test_exact_beats_truncated_when_nondominant` -- in f32 it does
    not merely limp here, it diverges outright.)
    """
    band, xstar, b = _banded_system(oscillatory_banded, 400, 6, 0.5, seed=0)
    sol = solve_banded(band, b, SaPOptions(p=8, variant=variant, tol=1e-5,
                                           maxiter=200))
    assert sol.converged
    assert sol.iterations <= budget
    err = np.linalg.norm(np.asarray(sol.x) - xstar) / np.linalg.norm(xstar)
    assert err < 1e-2
    assert sol.info["d_factor"] == pytest.approx(0.5, rel=1e-3)
    if variant == "auto":
        assert sol.info["variant"] == "E"  # d < 1 -> exact reduced system


def test_exact_beats_truncated_when_nondominant():
    """The point of SaP-E at d < 1: C either fails outright (f32: the
    truncated correction amplifies the non-decaying spike error until the
    iteration breaks down) or needs strictly more iterations than E."""
    band, _, b = _banded_system(oscillatory_banded, 400, 6, 0.5, seed=3)
    sol_e = solve_banded(band, b, SaPOptions(p=8, variant="E", tol=1e-5,
                                             maxiter=200))
    assert sol_e.converged and sol_e.iterations <= 10.0
    sol_c = solve_banded(band, b, SaPOptions(p=8, variant="C", tol=1e-5,
                                             maxiter=200))
    assert (not sol_c.converged) or sol_c.iterations > sol_e.iterations


@pytest.mark.parametrize("d,variant,budget,expect", [
    (1.5, "auto", 10.0, "C"),
    (0.3, "auto", 10.0, "E"),
    (0.3, "E", 10.0, "E"),
])
def test_sparse_pipeline_variants(d, variant, budget, expect):
    """Sparse front end (DB/CM reordering) + E/auto: the d-factor is
    estimated on the *reordered* preconditioner band."""
    csr = random_sparse(300, avg_nnz_per_row=5.0, d=d, shuffle=True, seed=5)
    dense = csr.to_dense()
    xstar = np.random.default_rng(6).normal(size=300)
    b = dense @ xstar

    pl = plan(csr, SaPOptions(p=4, variant=variant, tol=1e-6, maxiter=200))
    fac = factor(pl)
    assert fac.variant == expect
    res = fac.solve(jnp.asarray(b, jnp.float32))
    assert bool(res.converged)
    assert float(res.iterations) <= budget
    err = np.linalg.norm(np.asarray(res.x) - xstar) / np.linalg.norm(xstar)
    assert err < 1e-2


# ---------------------------------------------------------------------------
# the auto policy and its estimator
# ---------------------------------------------------------------------------


def test_resolve_variant_policy():
    assert resolve_variant("auto", 1.0) == "C"
    assert resolve_variant("auto", 2.5) == "C"
    assert resolve_variant("auto", 0.99) == "E"
    assert resolve_variant("auto", float("inf")) == "C"
    # explicit variants pass through untouched
    for v in ("C", "D", "E"):
        assert resolve_variant(v, 0.1) == v


@pytest.mark.parametrize("d", [0.06, 0.5, 1.0, 2.0])
def test_d_factor_estimator_matches_generator(d):
    """random_banded constructs |a_ii| = d * sum|off| with equality in at
    least one row, so the estimator must recover d (up to f32 rounding)."""
    band = jnp.asarray(random_banded(256, 5, d=d, seed=4), jnp.float32)
    assert float(diag_dominance_factor(band)) == pytest.approx(d, rel=1e-3)


def test_d_factor_diagonal_matrix_is_inf():
    band = jnp.zeros((16, 5)).at[:, 2].set(3.0)
    assert np.isinf(float(diag_dominance_factor(band)))


def test_factorization_carries_d_factor():
    band = jnp.asarray(random_banded(128, 4, d=0.7, seed=1), jnp.float32)
    fac = factor(plan_banded(band, SaPOptions(p=4, variant="auto")))
    assert fac.variant == "E"
    assert float(fac.d_factor) == pytest.approx(0.7, rel=1e-3)


# ---------------------------------------------------------------------------
# solve_many: per-RHS diagnostics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["D", "C", "E", "auto"])
def test_solve_many_per_rhs_diagnostics(variant):
    n, k, r = 256, 4, 5
    band = jnp.asarray(oscillatory_banded(n, k, d=0.5, seed=7), jnp.float32)
    dense = np.asarray(band_to_dense(band))
    xs = np.random.default_rng(8).normal(size=(n, r))
    bmat = jnp.asarray(dense @ xs, jnp.float32)

    fac = factor(plan_banded(band, SaPOptions(p=4, variant=variant, tol=1e-5,
                                              maxiter=300)))
    res = fac.solve_many(bmat)
    assert res.x.shape == (n, r)
    assert res.iterations.shape == (r,)
    assert res.resnorm.shape == (r,)
    assert res.converged.shape == (r,)
    assert bool(res.converged.all())
    assert res.d_factor.shape == ()  # one band -> one dominance estimate
    assert float(res.d_factor) == pytest.approx(0.5, rel=1e-3)
    err = np.abs(np.asarray(res.x) - xs).max()
    assert err < 5e-2
    # per-column runs are independent: each matches its single-RHS solve
    one = fac.solve(bmat[:, 0])
    assert float(one.iterations) == float(res.iterations[0])


# ---------------------------------------------------------------------------
# acceptance: at d ~ 0.5, E (and auto) reach 1e-8 in <= 100 iterations
# where C cannot (float64, subprocess -- the x64 flag is process-global)
# ---------------------------------------------------------------------------

ACCEPTANCE_SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import SaPOptions, factor, plan_banded
from repro.core.banded import band_to_dense, oscillatory_banded

n, k, p = 2048, 16, 32
band = jnp.asarray(oscillatory_banded(n, k, d=0.5, seed=0))
dense = np.asarray(band_to_dense(band))
xstar = np.random.default_rng(0).normal(size=n)
b = jnp.asarray(dense @ xstar)

results = {}
for v in ("C", "E", "auto"):
    opts = SaPOptions(p=p, variant=v, tol=1e-8, maxiter=100,
                      precond_dtype="float64")
    fac = factor(plan_banded(band, opts))
    r = fac.solve(b)
    results[v] = (bool(r.converged), float(r.iterations), float(r.resnorm),
                  fac.variant)
    print(v, results[v])

conv_c, it_c, res_c, _ = results["C"]
conv_e, it_e, res_e, _ = results["E"]
conv_a, it_a, res_a, va = results["auto"]
assert not conv_c, f"C unexpectedly converged: {results['C']}"
assert res_c > 1e-8
assert conv_e and it_e <= 100 and res_e <= 1e-8, results["E"]
assert va == "E"
assert conv_a and it_a <= 100 and res_a <= 1e-8, results["auto"]
print("VARIANT_ACCEPTANCE_OK")
"""


def test_exact_variant_acceptance_d05_f64():
    proc = subprocess.run(
        [sys.executable, "-c", ACCEPTANCE_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu", "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "VARIANT_ACCEPTANCE_OK" in proc.stdout
