"""Documentation checks: intra-repo link validation + runnable quickstart.

Two gates, both wired into the CI ``docs-check`` job:

1. every relative markdown link in README.md and docs/*.md resolves to a
   file that exists in the repo (anchors and external URLs are skipped);
2. the first ```python code block in README.md actually runs -- the
   quickstart is a promise, not an illustration.

Run locally::

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_FENCE = re.compile(r"^```")
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files.extend(sorted((REPO / "docs").glob("*.md")))
    return [f for f in files if f.exists()]


def _strip_code_blocks(text: str) -> str:
    """Remove fenced code blocks so link syntax inside them is ignored."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links() -> list[str]:
    """Return a list of human-readable broken-link descriptions."""
    errors = []
    for doc in _doc_files():
        body = _strip_code_blocks(doc.read_text())
        for target in _LINK.findall(body):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                rel = doc.relative_to(REPO)
                errors.append(f"{rel}: broken link -> {target}")
    return errors


def extract_quickstart(readme: Path) -> str:
    """First ```python fenced block in the README."""
    lines = readme.read_text().splitlines()
    block: list[str] = []
    in_block = False
    for line in lines:
        if not in_block and line.strip() == "```python":
            in_block = True
            continue
        if in_block:
            if line.strip() == "```":
                return "\n".join(block)
            block.append(line)
    raise SystemExit("README.md has no ```python code block to smoke-test")


def run_quickstart() -> int:
    code = extract_quickstart(REPO / "README.md")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, cwd=REPO,
        capture_output=True, text=True, timeout=600,
    )
    if proc.returncode != 0:
        sys.stderr.write("README quickstart block failed:\n")
        sys.stderr.write(proc.stdout[-2000:] + "\n" + proc.stderr[-4000:] + "\n")
    else:
        print(f"quickstart OK: {proc.stdout.strip()!r}")
    return proc.returncode


def main() -> int:
    errors = check_links()
    for err in errors:
        sys.stderr.write(err + "\n")
    n_docs = len(_doc_files())
    print(f"checked links in {n_docs} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken'}")
    rc = run_quickstart()
    return 1 if (errors or rc != 0) else 0


if __name__ == "__main__":
    raise SystemExit(main())
